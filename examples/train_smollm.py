"""End-to-end training driver: train a ~135M-family model for a few hundred
steps on the synthetic pipeline with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_smollm.py --steps 200
(Use --full on a real pod to train the actual 135M config; the smoke config
keeps CPU runtime reasonable while exercising the identical code path.)
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import ShapeSpec
    from repro.training.train_loop import TrainConfig, train

    cfg = (get_config if args.full else get_smoke_config)("smollm-135m")
    shape = ShapeSpec("example", args.seq, args.batch, "train")
    tcfg = TrainConfig(steps=args.steps, log_every=10, save_every=50,
                       ckpt_dir="artifacts/ckpt_smollm",
                       grad_compression=args.compress)
    state, losses, monitor = train(cfg, tcfg, shape)
    print(f"\ntrained {args.steps} steps: loss {losses[0][1]:.4f} -> "
          f"{losses[-1][1]:.4f}; {len(monitor.events)} straggler events; "
          f"checkpoints in {tcfg.ckpt_dir}")


if __name__ == "__main__":
    main()
