"""Dynamic sequence lengths (paper Fig 14): serve misaligned prompt lengths
under all four strategies and compare wall times + compile counts.

    PYTHONPATH=src python examples/dynamic_prompts.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax


def main():
    from repro.configs import get_smoke_config
    from repro.core.engine import InferenceEngine

    cfg = get_smoke_config("llama3-8b")
    lengths = [135, 300, 525, 300, 135, 525]   # repeats exercise graph reuse

    print(f"{'strategy':16s} {'total_s':>8s} {'compile_s':>10s}")
    for strategy in ("online-prepare", "padding", "pipe", "hetero"):
        eng = InferenceEngine(cfg, mode="xla", prefill_strategy=strategy,
                              buckets=(64, 128, 256), max_len=1024)
        t0 = time.perf_counter()
        for i, S in enumerate(lengths):
            prompt = jax.random.randint(jax.random.PRNGKey(i), (1, S), 0,
                                        cfg.vocab_size)
            eng.generate(prompt, max_new_tokens=2)
        dt = time.perf_counter() - t0
        print(f"{strategy:16s} {dt:8.2f} {eng.stats.compile_s:10.2f}")


if __name__ == "__main__":
    main()
