"""Quickstart: the full HeteroInfer pipeline on one model, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. profile the two execution paths for the model's weight shapes,
2. solve tensor-partitioning decisions (weight/activation/hybrid),
3. serve a prompt with the hetero engine (bucketed prefill + on-device
   fast-sync decode), comparing against the flexible-path-only baseline.
Runs the reduced smoke config on CPU; point --arch/--full at a real TPU pod
for the production configs.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.core.engine import InferenceEngine
    from repro.core.profiler import profile_analytic
    from repro.core.solver import PartitionSolver

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    print(f"== {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.n_params/1e6:.0f}M params) ==")

    # 1/2. offline: profile + solve (uses the FULL config's weight shapes —
    # the plan is about the deploy target even when serving the smoke model)
    full = get_config(args.arch)
    table = profile_analytic(full)
    plan = PartitionSolver(table, sync_mode="fast").solve(full)
    print("\nsolver decisions (selected):")
    for (site, M), d in list(plan.decisions.items())[:6]:
        print("  ", d.describe())
    print(f"  ... {len(plan.decisions)} decisions; decode KV layout: "
          f"{plan.kv_mode}")

    # 3. online: serve
    prompt = jax.random.randint(jax.random.PRNGKey(0), (1, 300), 0,
                                cfg.vocab_size)
    for mode, fast in (("xla", False), ("hetero-tensor", True)):
        eng = InferenceEngine(cfg, mode=mode, fast_sync=fast, max_len=512)
        toks = eng.generate(prompt, max_new_tokens=16)
        tps = eng.stats.tokens_per_s()
        print(f"\nmode={mode:14s} fast_sync={fast}: "
              f"prefill {tps['prefill_tok_s']:.0f} tok/s, "
              f"decode {tps['decode_tok_s']:.1f} tok/s")
        print("   generated:", toks[0, :8].tolist(), "...")


if __name__ == "__main__":
    main()
