"""Hetero-mode paged serving, end to end: solver-planned prefill + fused-
window (fast-sync) decode over the paged KV pool.

    PYTHONPATH=src python examples/hetero_serve.py --requests 6

Admission-time prefill routes every matmul (including the LM head) through
the HeteroCtx whose PartitionSolver plan was solved offline for this model
(paper §4.1/§4.2); decode runs as fused on-device windows — ONE host
dispatch per `--window` decode steps instead of one per token (§4.3, the
clFinish problem at serving widths). The host-synced dense-prefill arm runs
for comparison: identical greedy tokens, ~window-times fewer dispatches.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=17)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--engine-mode", default="hetero-tensor",
                    choices=["xla", "mxu", "hetero-layer", "hetero-tensor"])
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.serving.scheduler import PagedBatcher, Request

    cfg = get_smoke_config(args.arch)
    max_len = 200 + args.new_tokens

    def requests():
        r = np.random.default_rng(1)
        return [Request(rid=i,
                        prompt=r.integers(0, cfg.vocab_size,
                                          int(r.integers(16, 200))
                                          ).astype(np.int32),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]

    def serve(label, **kw):
        pb = PagedBatcher(cfg,
                          num_blocks=1 + args.requests * -(-max_len // 32),
                          block_size=32, max_blocks_per_seq=-(-max_len // 32),
                          decode_width=args.requests, buckets=(32, 64, 128),
                          **kw)
        reqs = requests()
        t0 = time.perf_counter()
        pb.run(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in reqs)
        print(f"{label}: {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s); "
              f"decode: {pb.decode_dispatches} host dispatches for "
              f"{pb.decode_steps} tokens "
              f"({pb.decode_steps/max(pb.decode_dispatches,1):.1f} "
              f"tokens/dispatch)")
        return reqs

    print(f"== {cfg.name}: {args.requests} requests, "
          f"{args.new_tokens} new tokens each ==")
    base = serve("host-synced baseline      ", sync="host")
    fused = serve(f"hetero + window={args.window} fused ", sync="device",
                  window=args.window, engine_mode=args.engine_mode)
    match = all(b.output == f.output for b, f in zip(base, fused))
    print(f"greedy outputs identical across arms: {match}")
    assert match, "hetero/fused arm diverged from the baseline"
    for r in fused[:2]:
        print(f"  req{r.rid} prompt_len={len(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
