"""Batched serving driver: continuous batching over a shared KV cache with
bucket-chunked (activation-centric) prefill admission.

    PYTHONPATH=src python examples/serve_batch.py --requests 12
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.serving.sampler import SamplerConfig
    from repro.serving.scheduler import ContinuousBatcher, Request

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(16, 200))
                                        ).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    cb = ContinuousBatcher(cfg, max_batch=args.max_batch, max_len=256,
                           buckets=(32, 64, 128),
                           sampler=SamplerConfig(temperature=0.8, top_k=40))
    t0 = time.perf_counter()
    cb.run(reqs)
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"{done}/{len(reqs)} requests complete, {toks} tokens "
          f"in {dt:.2f}s -> {toks/dt:.1f} tok/s aggregate "
          f"(batch slots: {args.max_batch})")
    for r in reqs[:3]:
        print(f"  req{r.rid} prompt_len={len(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
