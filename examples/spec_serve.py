"""Heterogeneous speculative decoding, end to end: draft on the flexible
path, one solver-planned K+1-token verify dispatch per round, paged
rollback.

    PYTHONPATH=src python examples/spec_serve.py --requests 4 --spec-k 4

Two serving arms over the same workload:
  * plain paged decode — one target dispatch per token (the paper's decode
    bottleneck: M=1 is memory-bound flexible-path work);
  * speculative decoding (``PagedBatcher(spec=...)``) — a draft model
    proposes K tokens per lane per round, ONE batched ``paged_verify``
    target dispatch scores all K+1 positions (the solver's VERIFY site
    class under --engine-mode), greedy acceptance emits 1..K+1 tokens, and
    ``PagedKVCache.truncate_to`` reclaims rejected blocks.

Greedy verification is lossless, so both arms print identical tokens; the
spec arm simply pays fewer target dispatches per token (self-speculation
here, the acceptance-rate upper bound — pass --spec-draft for a real
second model, e.g. smollm-135m, and watch acceptance and the dispatch win
shrink with a random-init draft).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=17)
    ap.add_argument("--spec-k", type=int, default=4, dest="spec_k")
    ap.add_argument("--spec-draft", default=None, dest="spec_draft",
                    help="draft config name; default self-speculation")
    ap.add_argument("--engine-mode", default=None,
                    choices=["xla", "mxu", "hetero-layer", "hetero-tensor"])
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.serving.scheduler import PagedBatcher, Request
    from repro.serving.spec import SpecConfig

    cfg = get_smoke_config(args.arch)
    max_len = 120 + args.new_tokens

    def requests():
        r = np.random.default_rng(1)
        return [Request(rid=i,
                        prompt=r.integers(0, cfg.vocab_size,
                                          int(r.integers(16, 120))
                                          ).astype(np.int32),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]

    def serve(label, **kw):
        pb = PagedBatcher(cfg,
                          num_blocks=1 + args.requests * -(-max_len // 32),
                          block_size=32, max_blocks_per_seq=-(-max_len // 32),
                          decode_width=args.requests, buckets=(32, 64),
                          **kw)
        reqs = requests()
        t0 = time.perf_counter()
        pb.run(reqs)
        dt = time.perf_counter() - t0
        s = pb.stats()
        toks = sum(len(r.output) for r in reqs)
        line = (f"{label}: {toks} tokens, {s['total_dispatches']} target "
                f"dispatches ({toks / s['total_dispatches']:.1f} "
                f"tokens/target-dispatch) in {dt:.2f}s")
        if "acceptance_rate" in s:
            line += (f"; {s['verify_dispatches']} verifies, acceptance "
                     f"{s['acceptance_rate']:.2f} (draft={s['draft_model']},"
                     f" {s['draft_dispatches']} draft dispatches)")
        print(line)
        return reqs

    print(f"== {cfg.name}: {args.requests} requests, "
          f"{args.new_tokens} new tokens each ==")
    base = serve("plain decode        ")
    spec = serve(f"speculative (K={args.spec_k}) ",
                 spec=SpecConfig(k=args.spec_k, draft=args.spec_draft,
                                 smoke=True),
                 engine_mode=args.engine_mode)
    match = all(b.output == s.output for b, s in zip(base, spec))
    print(f"greedy outputs identical across arms: {match}")
    assert match, "speculative arm diverged from plain greedy decode"
    for r in spec[:2]:
        print(f"  req{r.rid} prompt_len={len(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
