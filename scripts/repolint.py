#!/usr/bin/env python
"""repolint CLI — run the repo's AST invariant linter.

Usage:
    python scripts/repolint.py --check              # CI gate (exit 1 on new
                                                    # or stale findings)
    python scripts/repolint.py --list-rules
    python scripts/repolint.py --update-baseline    # regenerate baseline

Pure stdlib + the repro.analysis package (no jax import), so CI can run it
on a bare python with no project dependencies installed.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.core import (  # noqa: E402
    BASELINE_NAME, Baseline, rule_registry, run_repolint)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on findings not covered by the "
                         "baseline (and on stale baseline entries)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the current findings to the baseline file")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline path (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--root", type=Path, default=REPO,
                    help="repo root to lint (default: this repo)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, (kind, fn) in sorted(rule_registry().items()):
            doc = (fn.__doc__ or "").strip().splitlines()
            print(f"{name:18} [{kind:7}] {doc[0] if doc else ''}")
        return 0

    root = args.root.resolve()
    baseline_path = args.baseline or root / BASELINE_NAME
    rules = tuple(r.strip() for r in args.rules.split(",")) \
        if args.rules else None

    report = run_repolint(root, rules=rules,
                          baseline=Baseline.load(baseline_path))

    if args.update_baseline:
        Baseline.from_findings(report.findings).save(baseline_path)
        print(f"[repolint] wrote {len(report.findings)} fingerprint(s) "
              f"to {baseline_path}")
        return 0

    for f in report.new:
        print(f.render())
    for fp in report.stale:
        print(f"stale baseline entry (no longer fires): {fp}")
    print(report.summary())
    if not report.ok:
        print("[repolint] FAIL — fix the finding, or suppress with "
              "'# repolint: disable=<rule> -- <reason>' on the flagged line")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
