#!/usr/bin/env python
"""Regenerate the §Roofline table addendum in EXPERIMENTS.md from the
current artifacts/dryrun. Idempotent: replaces everything after the
ADDENDUM marker."""
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.roofline.analysis import analyze_all, markdown_table  # noqa: E402

MARKER = "<!-- ROOFLINE-ADDENDUM -->"


def main():
    cells = analyze_all()
    ok = [c for c in cells if c.ok and not c.skipped]
    table = markdown_table(cells)
    n_dom = {}
    for c in ok:
        n_dom[c.dominant] = n_dom.get(c.dominant, 0) + 1
    fits = sum(1 for c in ok if c.hbm_gb_per_chip <= 16.0)
    addendum = f"""{MARKER}

## §Roofline — final table (single-pod 16x16, post-§Perf code)

{table}

Summary: {len(ok)} runnable cells analyzed; dominant terms: {n_dom};
{fits}/{len(ok)} cells fit 16GB/chip HBM per `memory_analysis`
(the exceptions are recorded as open §Perf items). `useful ratio` near 1.0
means compiled FLOPs ≈ analytic model FLOPs (no hidden recompute/dispatch
waste); rows marked `scan-raw(undercounted)` lack probe pairs and
undercount scan bodies. The best cells sit at 0.7-0.9 of the compute
roofline (dbrx train post-fix, llama3/chameleon/qwen3 train); decode cells
are memory/HBM-stream bound by nature — the split-KV path puts llama3
decode at ~24% of its KV-stream bound on the raw metric (>=40%
TPU-corrected, see §Perf).
"""
    p = Path("EXPERIMENTS.md")
    text = p.read_text()
    if MARKER in text:
        text = text.split(MARKER)[0]
    p.write_text(text.rstrip() + "\n\n" + addendum)
    print(f"updated EXPERIMENTS.md with {len(cells)} rows ({len(ok)} analyzed)")


if __name__ == "__main__":
    main()
