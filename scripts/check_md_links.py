"""Fail on broken intra-repo Markdown links (the CI docs job).

Walks every tracked ``*.md`` file, extracts ``[text](target)`` links, and
checks that each relative (non-http, non-anchor) target exists on disk,
resolved against the linking file's directory. External URLs and pure
``#anchor`` links are skipped.

    python scripts/check_md_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SKIP_DIRS = {".git", "artifacts", "__pycache__", ".pytest_cache"}
# [text](target) — target up to the first unescaped ')' or whitespace
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files() -> list[Path]:
    return [p for p in sorted(ROOT.rglob("*.md"))
            if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts)]


def main() -> int:
    broken: list[str] = []
    n_links = 0
    for md in md_files():
        for m in LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            n_links += 1
            if not (md.parent / path).exists():
                broken.append(f"{md.relative_to(ROOT)} -> {target}")
    if broken:
        print(f"{len(broken)} broken intra-repo markdown link(s):")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"markdown links OK ({n_links} intra-repo links across "
          f"{len(md_files())} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
