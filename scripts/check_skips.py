#!/usr/bin/env python3
"""CI gate against silent coverage loss, two checks:

1. skips — the tier-1 run's skip count must EQUAL the allowlisted number
   (currently zero — both former perpetual skips were made hermetic /
   collection-filtered). A new `pytest.skip` that creeps in fails CI
   instead of silently shrinking coverage; a legitimately environment-gated
   skip must be added to ALLOWED_SKIPS here, with a reason, in the same PR.
2. presence — every test module in EXPECTED_MODULES must contribute at
   least one testcase to the junit report, so a collection error, an
   accidental deselection, or a deleted file can't silently drop a whole
   module (new test files must be added here in the PR that creates them).

Usage:  pytest -q --junitxml=report.xml && python scripts/check_skips.py report.xml
"""
from __future__ import annotations

import sys
import xml.etree.ElementTree as ET

# (test id substring -> reason). Empty: the tier-1 selection never skips.
ALLOWED_SKIPS: dict[str, str] = {}

# every tests/test_*.py module must show up in the tier-1 report
EXPECTED_MODULES = (
    "test_analysis",
    "test_attention", "test_core", "test_distributed", "test_fused_decode",
    "test_ingress", "test_kernel_conformance", "test_kernels",
    "test_mixed_batch", "test_models", "test_paged_cache",
    "test_prefix_cache", "test_quant_quality", "test_sampler",
    "test_scheduler_fuzz", "test_serving", "test_solver_properties",
    "test_spec", "test_system", "test_telemetry", "test_tp_serving",
    "test_trace", "test_training",
)


def main(path: str) -> int:
    root = ET.parse(path).getroot()
    suites = root.iter("testsuite") if root.tag == "testsuites" else [root]
    skipped = []
    seen_modules = set()
    total = errors = failures = 0
    for s in suites:
        total += int(s.get("tests", 0))
        errors += int(s.get("errors", 0))
        failures += int(s.get("failures", 0))
        for case in s.iter("testcase"):
            # classname is a dotted path (e.g. "tests.test_spec[.Class]");
            # record every component so module membership checks work
            seen_modules.update((case.get("classname") or "").split("."))
            if case.find("skipped") is not None:
                skipped.append(f"{case.get('classname')}::{case.get('name')}")
    unexpected = [t for t in skipped
                  if not any(k in t for k in ALLOWED_SKIPS)]
    # stale allowlist entries are as much a bug as silent skips: an entry
    # whose test no longer skips (or no longer exists) must be removed
    unmatched = [k for k in ALLOWED_SKIPS
                 if not any(k in t for t in skipped)]
    missing = [m for m in EXPECTED_MODULES if m not in seen_modules]
    print(f"[check_skips] {total} tests, {failures} failures, "
          f"{errors} errors, {len(skipped)} skipped "
          f"(allowlist entries: {len(ALLOWED_SKIPS)}; "
          f"{len(seen_modules)} modules seen)")
    if unexpected or unmatched or missing:
        for t in unexpected:
            print(f"[check_skips]   unexpected skip: {t}")
        for k in unmatched:
            print(f"[check_skips]   stale allowlist entry: {k!r} "
                  f"({ALLOWED_SKIPS[k]})")
        for m in missing:
            print(f"[check_skips]   missing module: {m} contributed no "
                  "testcases (collection error or deselected?)")
        print("[check_skips] FAIL: every skip must match a reasoned "
              "allowlist entry in scripts/check_skips.py (and every entry "
              "must still skip), and every EXPECTED_MODULES file must "
              "contribute tests — or update the lists")
        return 1
    print("[check_skips] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "report.xml"))
