#!/usr/bin/env python3
"""CI gate for Chrome trace-event artifacts written by ``serving/trace.py``.

Structural invariants a well-formed trace must satisfy — Perfetto is
forgiving, so a trace can "load" while being subtly wrong; this checker
is not:

1. schema — top-level ``traceEvents`` list; every event carries ``ph``,
   ``pid``, ``tid``, and (except metadata) an integer ``ts``.
2. monotone timestamps — ``ts`` never decreases in file order (metadata
   "M" events excluded). The tracer emits in clock order; a violation
   means a span closed with a stale timestamp.
3. paired B/E — per (pid, tid), duration events nest like a bracket
   sequence: every "E" matches the innermost open "B" by name, nothing
   left open at EOF, and E.ts >= B.ts.
4. resolvable flows — every flow step/finish ("t"/"f") follows a start
   ("s") with the same id and cat, and every start is eventually
   finished ("f"), so request arrows never dangle in the viewer.

Usage:  python scripts/check_trace.py trace.json
Importable: ``validate(trace_dict) -> list[str]`` (empty == clean).
"""
from __future__ import annotations

import json
import sys

_PHASES_NEED_TS = {"B", "E", "i", "s", "t", "f", "X", "C"}


def validate(trace: dict) -> list[str]:
    """Return a list of violation messages (empty when the trace is clean)."""
    errors: list[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' missing or not a list"]

    last_ts: int | None = None
    open_spans: dict[tuple, list[tuple[str, int]]] = {}   # (pid,tid) -> stack
    flow_started: dict[tuple, int] = {}    # (cat, id) -> start index
    flow_finished: set[tuple] = set()

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            errors.append(f"event {i}: missing 'ph'")
            continue
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"event {i} ({ph!r}): missing pid/tid")
            continue
        if ph == "M":
            continue            # metadata carries no timestamp
        ts = ev.get("ts")
        if ph in _PHASES_NEED_TS and not isinstance(ts, int):
            errors.append(f"event {i} ({ph!r} {ev.get('name')!r}): "
                          f"non-integer ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"event {i} ({ph!r} {ev.get('name')!r}): ts {ts} "
                          f"< previous {last_ts} (non-monotone)")
        last_ts = ts

        key = (ev["pid"], ev["tid"])
        if ph == "B":
            open_spans.setdefault(key, []).append((ev.get("name", ""), ts))
        elif ph == "E":
            stack = open_spans.get(key)
            if not stack:
                errors.append(f"event {i}: 'E' {ev.get('name')!r} on "
                              f"{key} with no open 'B'")
                continue
            b_name, b_ts = stack.pop()
            e_name = ev.get("name", "")
            if e_name and e_name != b_name:
                errors.append(f"event {i}: 'E' name {e_name!r} does not "
                              f"match open 'B' {b_name!r} on {key}")
            if ts < b_ts:
                errors.append(f"event {i}: 'E' {e_name!r} ts {ts} before "
                              f"its 'B' ts {b_ts}")
        elif ph in ("s", "t", "f"):
            fkey = (ev.get("cat"), ev.get("id"))
            if ev.get("id") is None:
                errors.append(f"event {i}: flow {ph!r} without 'id'")
                continue
            if ph == "s":
                flow_started.setdefault(fkey, i)
            else:
                if fkey not in flow_started:
                    errors.append(f"event {i}: flow {ph!r} id={fkey[1]} "
                                  f"cat={fkey[0]!r} has no preceding 's'")
                if ph == "f":
                    flow_finished.add(fkey)

    for key, stack in open_spans.items():
        for name, ts in stack:
            errors.append(f"unclosed 'B' {name!r} on {key} (ts {ts})")
    for fkey, idx in flow_started.items():
        if fkey not in flow_finished:
            errors.append(f"flow id={fkey[1]} cat={fkey[0]!r} started at "
                          f"event {idx} but never finished ('f')")
    return errors


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print("usage: check_trace.py <trace.json>", file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        trace = json.load(f)
    errors = validate(trace)
    n = len(trace.get("traceEvents", []))
    if errors:
        for e in errors:
            print(f"FAIL: {e}")
        print(f"check_trace: {len(errors)} violations in {n} events")
        return 1
    print(f"check_trace: OK ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
