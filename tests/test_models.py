"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward/train step on CPU, shape + finiteness assertions; plus
prefill/decode consistency and the chunked-recurrence oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_smoke_config
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 64
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)

    loss, metrics = jax.jit(lambda p, t: model.loss(p, t, t))(params, toks)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    grads = jax.grad(lambda p: model.loss(p, toks, toks)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if not get_smoke_config(a).encoder_only])
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 33
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(batch=B, max_len=64)
    logits, cache = jax.jit(model.prefill)(params, toks, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, tok, cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache["index"]) == S + 1


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-1.7b", "zamba2-2.7b",
                                  "rwkv6-3b"])
def test_prefill_decode_consistency(arch):
    """decode(token_S | prefill(tokens[:S])) == prefill(tokens[:S+1]) logits."""
    cfg = get_smoke_config(arch).with_(param_dtype="float32",
                                       compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 21
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(batch=B, max_len=48, dtype=jnp.float32)
    ref, _ = model.prefill(params, toks, cache)
    cache = model.init_cache(batch=B, max_len=48, dtype=jnp.float32)
    _, cache = model.prefill(params, toks[:, :-1], cache)
    dec, _ = model.decode_step(params, toks[:, -1:], cache)
    assert jnp.max(jnp.abs(ref[:, 0] - dec[:, 0])) < 1e-4


def test_moe_consistency_with_high_capacity():
    cfg = get_smoke_config("dbrx-132b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(RNG, (2, 17), 0, cfg.vocab_size)
    cache = model.init_cache(batch=2, max_len=32, dtype=jnp.float32)
    ref, _ = model.prefill(params, toks, cache)
    cache = model.init_cache(batch=2, max_len=32, dtype=jnp.float32)
    _, cache = model.prefill(params, toks[:, :-1], cache)
    dec, _ = model.decode_step(params, toks[:, -1:], cache)
    assert jnp.max(jnp.abs(ref[:, 0] - dec[:, 0])) < 1e-4


def test_chunked_prefill_matches_full():
    """Bucket-chunked prefill (activation-centric serving path) == one shot."""
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 50
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(batch=B, max_len=64, dtype=jnp.float32)
    ref, _ = model.prefill(params, toks, cache)
    from repro.models import transformer
    cache = model.init_cache(batch=B, max_len=64, dtype=jnp.float32)
    out = None
    for start, end in [(0, 32), (32, 50)]:
        out, cache = transformer.prefill(params, toks[:, start:end], cache,
                                         cfg, start_index=start)
    assert jnp.max(jnp.abs(ref - out)) < 1e-4


def test_unroll_mode_matches_scan():
    """Cost-probe unrolled programs must be numerically identical."""
    for arch in ["llama3-8b", "zamba2-2.7b", "rwkv6-3b", "qwen2-moe-a2.7b"]:
        cfg = get_smoke_config(arch).with_(param_dtype="float32",
                                           compute_dtype="float32",
                                           remat=False)
        model = build_model(cfg)
        params = model.init(RNG)
        toks = jax.random.randint(RNG, (2, 32), 0, cfg.vocab_size)
        l1, _ = model.loss(params, toks, toks)
        l2, _ = model.loss(params, toks, toks, unroll=True)
        assert abs(float(l1) - float(l2)) < 1e-5, arch
