"""Perplexity-drift regression for quantized serving.

A seeded mini-eval scores one fixed token set, teacher-forced, on the REAL
``smollm-135m`` config (full 576-dim / 30-layer / 49k-vocab geometry — the
shape family whose per-channel scale statistics the smoke configs cannot
reproduce) under fp, int8, and W4A16 weights. The mean next-token NLL
(nats/token) under each format must stay within a pinned drift bound of the
fp score: quantized serving is only a win if the accuracy cost stays
bounded (the COTS-device accuracy/latency tradeoff, PAPERS.md
arxiv 2410.03613), and this test turns that claim into a regression gate.
``benchmarks/bench_quant.py`` reports the same drift metric next to tok/s
and peak concurrency.

Bounds are calibrated ~4x above the observed drift of the pinned seed so
they catch quantizer regressions (a broken scale rule shifts NLL by whole
nats) without flaking on BLAS/backend reassociation noise.
"""
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.quant import WEIGHT_FORMATS, quantize_params, score_nll

# pinned per-format NLL drift bounds, nats/token (fp score ~ ln(vocab) on
# the seeded random init; observed drift: int8 ~2e-3, w4a16 ~0.05)
DRIFT_BOUND = {"int8": 0.02, "w4a16": 0.25}


@pytest.fixture(scope="module")
def mini_eval():
    """(model, fp params, fixed token set, fp NLL) on real smollm-135m."""
    cfg = get_config("smollm-135m").with_(param_dtype="float32",
                                          compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(11))
    tokens = jax.random.randint(jax.random.PRNGKey(12), (2, 129),
                                0, cfg.vocab_size)
    return cfg, model, params, tokens, score_nll(model, params, tokens)


@pytest.mark.tier1
@pytest.mark.parametrize("fmt", WEIGHT_FORMATS)
def test_quant_nll_drift_within_pinned_bound(mini_eval, fmt):
    cfg, model, params, tokens, base = mini_eval
    qnll = score_nll(model, quantize_params(params, cfg, fmt), tokens)
    drift = abs(qnll - base)
    assert drift < DRIFT_BOUND[fmt], (
        f"{fmt}: NLL drift {drift:.4f} nats/token exceeds the pinned "
        f"bound {DRIFT_BOUND[fmt]} (fp {base:.4f} vs quant {qnll:.4f})")


@pytest.mark.tier1
def test_quant_formats_ordered_by_precision(mini_eval):
    """int8 (8-bit codes) must drift no more than W4A16's pinned bound and
    the fp score itself must be finite/sane — guards against a silently
    diverging eval making the drift bounds vacuous."""
    cfg, model, params, tokens, base = mini_eval
    assert 0.0 < base < 20.0
    int8 = abs(score_nll(model, quantize_params(params, cfg, "int8"),
                         tokens) - base)
    assert int8 < DRIFT_BOUND["w4a16"]
