"""Automatic prefix caching: refcounted allocator state machine, chained
block hashing, copy-on-write immutability, LRU eviction under pressure, and
the end-to-end serving property (suffix-only prefill, bit-exact outputs).

The correctness contract under test:
  * a hash-registered (cached) block is IMMUTABLE — it is never written by
    a sequence that merely shares it (copy-on-write duplicates first);
  * retention is not a leak — ``assert_drained`` holds with blocks parked
    refcount-0 in the cache, and eviction restores a fully-free pool;
  * reuse is an allocation-policy change, never a numerics change — warm
    greedy outputs match the cold path token for token.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.paged_cache import (BlockAccountingError, BlockAllocator,
                                       OutOfBlocks, PagedKVCache)
from repro.serving.scheduler import PagedBatcher, Request

BS = 16

# smoke_model: session-scoped fixture from conftest.py


def _ref_generate(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _tokens(seed, n, vocab=97):
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


# ------------------------------------------------- refcounted allocator --

def test_allocator_refcount_share_and_release():
    """incref lets two owners hold a block; it only leaves OWNED when the
    last reference drops."""
    a = BlockAllocator(6)
    (b,) = a.alloc(1)
    a.incref(b)
    assert a.refcount(b) == 2
    a.free([b])
    assert a.refcount(b) == 1 and a.n_free == 4     # still owned
    a.free([b])
    assert a.refcount(b) == 0 and a.n_free == 5     # now actually free
    a.check()


def test_allocator_retire_reactivate_evict_cycle():
    """OWNED -> CACHED (retire at refcount 0) -> OWNED (reactivate on a
    hit) and CACHED -> FREE (evict) keep the three-state invariant."""
    a = BlockAllocator(6)
    b1, b2 = a.alloc(2)
    assert a.retire([b1]) == [b1]                   # 1 -> 0: cached
    assert a.n_cached == 1 and a.n_free == 3
    a.incref(b2)
    assert a.retire([b2]) == []                     # 2 -> 1: stays owned
    a.check()
    a.reactivate(b1)
    assert a.n_cached == 0 and a.refcount(b1) == 1
    assert a.retire([b1]) == [b1]
    a.evict([b1])
    assert a.n_free == 4 and a.n_cached == 0
    a.free([b2])                                    # retire dropped 2 -> 1
    a.check()
    assert a.n_free == 5


def test_allocator_free_raises_on_null_and_double_free():
    """Hardened free: the null block and unowned blocks raise instead of
    silently corrupting the free+owned+cached accounting."""
    a = BlockAllocator(4)
    with pytest.raises(BlockAccountingError, match="null block"):
        a.free([0])
    (b,) = a.alloc(1)
    a.free([b])
    with pytest.raises(BlockAccountingError, match="double free"):
        a.free([b])
    with pytest.raises(BlockAccountingError, match="double free"):
        a.free([3])                                  # never allocated
    a.check()                                        # accounting intact


def test_allocator_misuse_raises_in_every_state():
    a = BlockAllocator(4)
    (b,) = a.alloc(1)
    with pytest.raises(BlockAccountingError):
        a.incref(2)                                  # incref of free block
    with pytest.raises(BlockAccountingError):
        a.reactivate(b)                              # owned, not cached
    with pytest.raises(BlockAccountingError):
        a.evict([b])                                 # owned, not cached
    a.retire([b])
    with pytest.raises(BlockAccountingError, match="double free"):
        a.free([b])                                  # cached, not owned
    a.evict([b])
    a.check()


# ----------------------------------------------------- hit / share / CoW --

def test_close_registers_and_reopen_shares_blocks(smoke_model):
    """Cold open/close retires full blocks into the cache; an identical
    prompt then shares the same PHYSICAL blocks and reports the resident
    prefix; the partial tail block is never cached."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=17, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    ids = _tokens(0, 40)                             # 2 full blocks + 8 tail
    seq = kv.open_sequence(prompt_tokens=40, total_tokens=48, token_ids=ids)
    assert seq.cached_tokens == 0 and kv.prefix_hits == 0
    first_blocks = list(seq.blocks)
    seq.length = 40
    kv.close_sequence(seq, token_ids=ids)
    assert kv.allocator.n_cached == 2                # full blocks retained
    assert kv.allocator.n_free == 16 - 2             # tail freed

    seq2 = kv.open_sequence(prompt_tokens=40, total_tokens=48, token_ids=ids)
    assert seq2.cached_tokens == 2 * BS
    assert seq2.blocks[:2] == first_blocks[:2]       # same physical blocks
    assert seq2.blocks[2] not in kv._hash_of_block   # tail: fresh, uncached
    assert kv.prefix_hits == 1 and kv.prefix_tokens_reused == 2 * BS
    seq2.length = 40
    kv.close_sequence(seq2, token_ids=ids)
    kv.assert_drained()


def test_hit_stops_at_first_divergent_block(smoke_model):
    """The chain hash is prefix-dependent: a prompt diverging inside block
    i reuses exactly the blocks before i, even if later windows match."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=17, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    ids = _tokens(1, 3 * BS + 5)
    seq = kv.open_sequence(prompt_tokens=len(ids), total_tokens=len(ids) + 8,
                           token_ids=ids)
    seq.length = len(ids)
    kv.close_sequence(seq, token_ids=ids)

    fork = ids.copy()
    fork[BS + 3] += 1                                # diverge inside block 1
    seq2 = kv.open_sequence(prompt_tokens=len(fork),
                            total_tokens=len(fork) + 8, token_ids=fork)
    assert seq2.cached_tokens == BS                  # block 0 only
    seq2.length = len(fork)
    kv.close_sequence(seq2, token_ids=fork)
    kv.assert_drained()


def test_full_prompt_hit_copies_on_write(smoke_model):
    """A hit covering the WHOLE prompt must not hand the last cached block
    to the new sequence for its logits re-run: the block is duplicated
    (CoW) with identical pool contents, the original stays registered and
    unwritten, and the resident prefix is prompt-1 tokens."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=17, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    ids = _tokens(2, 2 * BS)                         # exact block multiple
    seq = kv.open_sequence(prompt_tokens=2 * BS, total_tokens=2 * BS + 8,
                           token_ids=ids)
    seq.length = 2 * BS
    # simulate prefill having written distinctive KV into the pool
    marker = jnp.arange(kv.pool["k"].size, dtype=jnp.float32
                        ).reshape(kv.pool["k"].shape) / 1000.
    kv.pool = {"k": marker, "v": -marker}
    orig = list(seq.blocks)
    kv.close_sequence(seq, token_ids=ids)

    seq2 = kv.open_sequence(prompt_tokens=2 * BS, total_tokens=2 * BS + 8,
                            token_ids=ids)
    assert seq2.cached_tokens == 2 * BS - 1          # one token to re-run
    assert kv.cow_copies == 1
    assert seq2.blocks[0] == orig[0]                 # first block shared
    copy = seq2.blocks[1]
    assert copy != orig[1]                           # last block duplicated
    for key in ("k", "v"):                           # contents bit-identical
        np.testing.assert_array_equal(np.asarray(kv.pool[key][:, copy]),
                                      np.asarray(kv.pool[key][:, orig[1]]))
    assert kv.allocator.refcount(orig[1]) == 0       # original: cached, idle
    assert kv.allocator.refcount(copy) == 1          # copy: private
    seq2.length = 2 * BS
    kv.close_sequence(seq2, token_ids=ids)
    kv.assert_drained()


def test_shared_block_never_written_by_two_owners(smoke_model):
    """Immutability property: for any admitted sequence, every position it
    may still write (cached_tokens .. total) maps to a PRIVATE block —
    sweep prompt lengths across block-boundary cases, with the cache
    pre-seeded so hits of every depth occur."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=33, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    base = _tokens(3, 4 * BS)
    seed = kv.open_sequence(prompt_tokens=len(base),
                            total_tokens=len(base) + 4, token_ids=base)
    seed.length = len(base)
    kv.close_sequence(seq=seed, token_ids=base)

    for S in (BS - 1, BS, BS + 1, 2 * BS, 3 * BS - 1, 3 * BS, 4 * BS):
        ids = base[:S]
        total = S + 8
        seq = kv.open_sequence(prompt_tokens=S, total_tokens=total,
                               token_ids=ids)
        shared = set(seq.blocks[:seq.n_shared])
        kv.grow_to(seq, total)                       # cover every write
        for p in range(seq.cached_tokens, total):
            owner = seq.table[p // BS]
            assert owner not in shared, (S, p)
            assert kv.allocator.refcount(int(owner)) == 1, (S, p)
        seq.length = S
        kv.close_sequence(seq, token_ids=ids)
    kv.assert_drained()


def test_concurrent_identical_prompts_dedup_on_close(smoke_model):
    """Two live sequences with the same prompt admitted before either
    closes: neither hits (registration happens at close), and closing both
    registers the content ONCE — the duplicate's blocks free normally."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=17, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    ids = _tokens(4, 2 * BS + 4)
    seqs = [kv.open_sequence(prompt_tokens=len(ids),
                             total_tokens=len(ids) + 4, token_ids=ids)
            for _ in range(2)]
    assert all(s.cached_tokens == 0 for s in seqs)
    for s in seqs:
        s.length = len(ids)
        kv.close_sequence(s, token_ids=ids)
    assert kv.allocator.n_cached == 2                # one copy, not two
    kv.assert_drained()


# ----------------------------------------------------------- eviction --

def test_eviction_is_lru_and_restores_capacity(smoke_model):
    """Allocation pressure reclaims refcount-0 cached blocks least recently
    used first: the oldest content stops hitting, the freshest still hits,
    and a full-pool allocation succeeds despite retention."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)                     # 8 usable
    streams = [_tokens(10 + i, 2 * BS) for i in range(3)]
    for ids in streams:                              # retire 3x2 blocks
        seq = kv.open_sequence(prompt_tokens=2 * BS,
                               total_tokens=2 * BS, token_ids=ids)
        seq.length = 2 * BS
        kv.close_sequence(seq, token_ids=ids)
    assert kv.allocator.n_cached == 6 and kv.allocator.n_free == 2

    # admitting 4 blocks must evict the two LRU blocks (stream 0)
    big = _tokens(99, 4 * BS - 4)
    seq = kv.open_sequence(prompt_tokens=len(big), total_tokens=len(big),
                           token_ids=big)
    assert kv.evictions == 2
    assert kv.allocator.n_cached == 4
    seq.length = len(big)
    kv.close_sequence(seq, token_ids=big)

    # stream 0 was evicted -> cold; stream 2 (freshest) still hits.
    # opening stream 2 FIRST also pins its blocks against the eviction
    # that admitting stream 0 cold will trigger.
    s2 = kv.open_sequence(prompt_tokens=2 * BS, total_tokens=2 * BS,
                          token_ids=streams[2])
    assert s2.cached_tokens == 2 * BS - 1            # full-prompt CoW hit
    s0 = kv.open_sequence(prompt_tokens=2 * BS, total_tokens=2 * BS,
                          token_ids=streams[0])
    assert s0.cached_tokens == 0                     # LRU-evicted: cold
    for s, ids in ((s2, streams[2]), (s0, streams[0])):
        s.length = 2 * BS
        kv.close_sequence(s, token_ids=ids)
    kv.assert_drained()


def test_out_of_blocks_only_after_cache_drained(smoke_model):
    """OutOfBlocks fires only once free list AND evictable cache are
    exhausted; admission gating counts cached blocks as capacity."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=5, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)                     # 4 usable
    ids = _tokens(5, 2 * BS)
    seq = kv.open_sequence(prompt_tokens=2 * BS, total_tokens=2 * BS,
                           token_ids=ids)
    seq.length = 2 * BS
    kv.close_sequence(seq, token_ids=ids)
    assert kv.allocator.n_free == 2 and kv.allocator.n_cached == 2
    assert kv.can_admit(4 * BS)                      # cached counts
    other = _tokens(6, 4 * BS)
    seq = kv.open_sequence(prompt_tokens=4 * BS, total_tokens=4 * BS,
                           token_ids=other)
    assert kv.evictions == 2                         # cache gave way
    with pytest.raises(OutOfBlocks):
        kv.open_sequence(prompt_tokens=BS, total_tokens=BS)
    seq.length = 4 * BS
    kv.close_sequence(seq, token_ids=other)
    kv.assert_drained()


def test_truncate_refuses_rollback_into_shared_prefix(smoke_model):
    """Spec-decoding rollback can never free a shared cached block: rolling
    back below the resident prefix raises."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=BS, dtype=jnp.float32,
                      prefix_cache=True)
    ids = _tokens(7, 2 * BS + 4)
    seq = kv.open_sequence(prompt_tokens=len(ids), total_tokens=len(ids) + 8,
                           token_ids=ids)
    seq.length = len(ids)
    kv.close_sequence(seq, token_ids=ids)
    seq = kv.open_sequence(prompt_tokens=len(ids), total_tokens=len(ids) + 8,
                           token_ids=ids)
    assert seq.cached_tokens == 2 * BS
    with pytest.raises(ValueError, match="shared cached prefix"):
        kv.truncate_to(seq, BS)
    seq.length = len(ids)
    assert kv.truncate_to(seq, len(ids)) == 0        # at the prompt: fine
    kv.close_sequence(seq, token_ids=ids)
    kv.assert_drained()


# ------------------------------------------------------- end to end --

@pytest.mark.tier1
def test_batcher_prefix_cache_exact_and_fewer_dispatches(smoke_model):
    """The serving property: a shared-system-prompt wave after a warm-up
    request produces bit-identical greedy outputs to the cold arm with
    strictly fewer prefill dispatches and fresh-block allocations, and the
    pool still drains (retention excluded)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(8)
    sys_prompt = rng.integers(0, cfg.vocab_size, 3 * BS).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, t).astype(np.int32)
             for t in (5, 11, 0, BS)]                # 0 => CoW admission

    def waves():
        w1 = [Request(rid=0, prompt=np.concatenate([sys_prompt, tails[0]]),
                      max_new_tokens=4)]
        w2 = [Request(rid=i + 1,
                      prompt=np.concatenate([sys_prompt, tails[i]]),
                      max_new_tokens=4) for i in range(len(tails))]
        return w1, w2

    outputs, stats, allocs = {}, {}, {}
    for prefix in (False, True):
        pb = PagedBatcher(cfg, params, num_blocks=33, block_size=BS,
                          decode_width=2, buckets=(32, 64),
                          cache_dtype=jnp.float32, prefix_cache=prefix)
        w1, w2 = waves()
        pb.run(w1)
        pb.run(w2)
        assert all(r.done for r in w1 + w2)
        pb.kv.assert_drained()
        outputs[prefix] = [r.output for r in w1 + w2]
        stats[prefix] = pb.stats()
        allocs[prefix] = pb.kv.allocator.total_allocs
    assert outputs[True] == outputs[False]
    ref = _ref_generate(model, params,
                        np.concatenate([sys_prompt, tails[1]]), 4)
    assert outputs[True][2] == ref                   # and both match dense
    assert stats[True]["prefill_dispatches"] < \
        stats[False]["prefill_dispatches"]
    assert allocs[True] < allocs[False]
    assert stats[True]["prefix_hits"] > 0
    assert stats[True]["cow_copies"] >= 1            # the len-0 tail
    assert stats[False]["prefix_hits"] == 0


def test_batcher_multi_turn_reuses_generated_blocks(smoke_model):
    """Conversation pattern: turn 2's prompt extends turn 1's prompt +
    REPLY, so the cache must hit on blocks containing generated-token KV
    (the close-time hash runs over the written stream, not the prompt)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(12)
    turn1 = rng.integers(0, cfg.vocab_size, 2 * BS + 3).astype(np.int32)
    n1 = 6
    pb = PagedBatcher(cfg, params, num_blocks=33, block_size=BS,
                      decode_width=2, buckets=(32, 64),
                      cache_dtype=jnp.float32, prefix_cache=True)
    r1 = Request(rid=0, prompt=turn1, max_new_tokens=n1)
    pb.run([r1])
    # turn 2: history = turn1 + the model's reply + new user tokens
    history = np.concatenate([turn1, np.asarray(r1.output, np.int32),
                              rng.integers(0, cfg.vocab_size, 5
                                           ).astype(np.int32)])
    r2 = Request(rid=1, prompt=history, max_new_tokens=4)
    pb.run([r2])
    s = pb.stats()
    assert s["prefix_hits"] == 1
    # the written stream of turn 1 covers 2*BS+3+n1-1 tokens -> its first
    # (2*BS+3+n1-1)//BS blocks are cached, INCLUDING one holding reply KV
    assert s["prefix_tokens_reused"] == ((len(turn1) + n1 - 1) // BS) * BS
    assert r2.output == _ref_generate(model, params, history, 4)
    pb.kv.assert_drained()
