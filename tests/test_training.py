"""Training substrate: optimizer, checkpoint (async + elastic), fault
tolerance (crash restart, straggler detection), data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.data import PackedFile, Prefetcher, SyntheticLM
from repro.training.fault_tolerance import RestartPolicy, StepMonitor
from repro.training.train_loop import TrainConfig, train

RNG = jax.random.PRNGKey(0)


def test_adamw_reduces_loss_quadratic():
    w = jnp.asarray([3.0, -2.0])
    state = opt.init_state({"w": w}, opt.AdamWConfig(lr=0.1, weight_decay=0.0,
                                                     warmup_steps=0))
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0)
    for _ in range(200):
        g = {"w": 2 * state["params"]["w"]}
        state, m = opt.apply_updates(state, g, cfg)
    assert float(jnp.abs(state["params"]["w"]).max()) < 0.2


def test_grad_clip_bounds_update():
    w = jnp.zeros((4,))
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0)
    state = opt.init_state({"w": w}, cfg)
    _, m = opt.apply_updates(state, {"w": jnp.full((4,), 1e6)}, cfg)
    assert float(m["grad_norm"]) > 1e5        # raw norm reported


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
             "step": jnp.asarray(7)}
    cm = CheckpointManager(tmp_path, keep=2)
    cm.save(7, state, blocking=True)
    out = cm.restore(7, state)
    assert (np.asarray(out["a"]) == np.asarray(state["a"])).all()
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert cm.latest_step() == 7


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one mesh sharding, restore under a different mesh."""
    mesh_a = make_host_mesh(4, 2)
    x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                       NamedSharding(mesh_a, P("data", "model")))
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"x": x}, blocking=True)
    mesh_b = make_host_mesh(2, 2)
    sh = {"x": NamedSharding(mesh_b, P("model", "data"))}
    out = cm.restore(1, {"x": x}, sh)
    assert out["x"].sharding.spec == P("model", "data")
    assert (np.asarray(out["x"]) == np.asarray(x)).all()


def test_checkpoint_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"x": jnp.zeros(3)}, blocking=True)
    assert cm.all_steps() == [3, 4]


def test_fault_tolerant_restart(tmp_path):
    """Inject a crash mid-run; training must restore and converge anyway."""
    cfg = get_smoke_config("smollm-135m")
    tcfg = TrainConfig(steps=30, save_every=10, log_every=10,
                       ckpt_dir=str(tmp_path))
    crashed = {"done": False}

    def injector(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    state, losses, monitor = train(cfg, tcfg, fail_injector=injector,
                                   log=lambda *a: None)
    assert crashed["done"]
    assert int(state["step"]) == 30          # completed despite the crash


def test_straggler_monitor():
    m = StepMonitor(straggler_factor=3.0)
    for i in range(10):
        assert not m.record(i, 0.1)
    assert m.record(10, 1.0)                 # 10x median -> flagged
    assert len(m.events) == 1


def test_train_clock_injectable(tmp_path):
    """Regression for the Clock migration: train()'s step timing reads the
    injected telemetry Clock (not time.perf_counter), so a FakeClock run
    records exactly the virtual durations the clock hands out."""
    from repro.serving.telemetry import FakeClock
    from repro.training.fault_tolerance import run_resilient

    class TickClock(FakeClock):
        def now(self):            # each read advances 1 virtual second
            t = super().now()
            self.advance(1.0)
            return t

    cfg = get_smoke_config("smollm-135m")
    tcfg = TrainConfig(steps=4, save_every=100, log_every=1,
                       ckpt_dir=str(tmp_path))
    lines = []
    state, losses, monitor = train(cfg, tcfg, log=lines.append,
                                   clock=TickClock())
    assert int(state["step"]) == 4
    # run_resilient's monitor saw clock-derived dts, never wall time
    assert all(dt > 0.0 for dt in monitor.times)
    assert all(float(dt) == int(float(dt)) for dt in monitor.times)

    # and a plain FakeClock (frozen time) yields dt == 0.0 for every step:
    # wall-clock-free by construction
    mon2 = StepMonitor()
    data = SyntheticLM(cfg.vocab_size, 16, 2, seed=0)
    st = {"step": 0}

    def step_fn(s, batch):
        return {"step": s["step"] + 1}, {}

    run_resilient(3, state=st, data=data, step_fn=step_fn,
                  ckpt=CheckpointManager(tmp_path / "c2"), monitor=mon2,
                  clock=FakeClock(), log=lambda *a: None)
    assert mon2.times == [0.0, 0.0, 0.0]


def test_synthetic_data_deterministic_and_restorable():
    d1 = SyntheticLM(1000, 32, 4, seed=3)
    batches = [d1.next() for _ in range(5)]
    d2 = SyntheticLM(1000, 32, 4, seed=3)
    d2.restore({"step": 3, "seed": 3})
    b = d2.next()
    assert (b["inputs"] == batches[3]["inputs"]).all()


def test_packed_file_pipeline(tmp_path):
    toks = np.random.default_rng(0).integers(0, 60000, 10000).astype(np.uint16)
    p = tmp_path / "tokens.bin"
    toks.tofile(p)
    src = PackedFile(p, vocab_size=50000, seq_len=16, batch=2)
    b1 = src.next()
    assert b1["inputs"].shape == (2, 16)
    assert (b1["inputs"] < 50000).all()
    pf = Prefetcher(src)
    b2 = pf.next()
    assert b2["inputs"].shape == (2, 16)
    pf.close()


def test_train_loss_decreases():
    cfg = get_smoke_config("smollm-135m")
    tcfg = TrainConfig(steps=60, save_every=1000, log_every=5,
                       ckpt_dir="artifacts/test_ckpt")
    state, losses, _ = train(cfg, tcfg, log=lambda *a: None)
    assert losses[-1][1] < losses[0][1]


def test_train_with_compression_converges():
    cfg = get_smoke_config("smollm-135m")
    tcfg = TrainConfig(steps=40, save_every=1000, log_every=5,
                       grad_compression=True, ckpt_dir="artifacts/test_ckpt2")
    state, losses, _ = train(cfg, tcfg, log=lambda *a: None)
    assert losses[-1][1] < losses[0][1] + 0.02
