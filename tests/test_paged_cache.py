"""Paged KV cache: allocator invariants, paged vs dense exactness, and the
block-granularity admission win over dense slots at equal memory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.paged_cache import (BlockAccountingError, BlockAllocator,
                                       OutOfBlocks, PagedKVCache)
from repro.serving.scheduler import ContinuousBatcher, PagedBatcher, Request


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def _ref_generate(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, prompt[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


# --------------------------------------------------------------- allocator --

def test_allocator_never_hands_out_null_block():
    a = BlockAllocator(8)
    got = a.alloc(7)
    assert 0 not in got and sorted(got) == list(range(1, 8))
    a.check()


def test_allocator_exhaustion_and_reuse():
    a = BlockAllocator(5)
    first = a.alloc(4)
    with pytest.raises(OutOfBlocks):
        a.alloc(1)
    a.free(first[:2])
    assert a.n_free == 2
    again = a.alloc(2)
    assert set(again) == set(first[:2])     # recycled
    a.check()


def test_allocator_double_free_raises():
    """Hardened free: a double free (or freeing the null block) raises
    BlockAccountingError instead of silently corrupting the accounting —
    works under ``python -O`` too, unlike the assert it replaced."""
    a = BlockAllocator(4)
    b = a.alloc(1)
    a.free(b)
    with pytest.raises(BlockAccountingError):
        a.free(b)
    with pytest.raises(BlockAccountingError):
        a.free([0])
    a.check()                           # invariant survived the misuse


def test_cache_reservation_accounting(smoke_model):
    """Admission reserves generation blocks; lazy growth draws on the
    reservation; close returns everything."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=16, dtype=jnp.float32)
    # 40-token prompt + 20 generated = 60 tokens -> 4 blocks reserved,
    # 3 allocated now (ceil(40/16))
    seq = kv.open_sequence(prompt_tokens=40, total_tokens=60)
    assert len(seq.blocks) == 3 and seq.reserved == 4
    assert kv.n_free_unreserved == 8 - 4
    assert not kv.can_admit(5 * 16)         # only 4 unreserved blocks left
    assert kv.can_admit(4 * 16)
    seq.length = 40
    for _ in range(20):                     # decode 20 tokens
        kv.maybe_grow(seq)
        seq.length += 1
    assert len(seq.blocks) == 4             # grew exactly once, at 48
    kv.close_sequence(seq)
    assert kv.allocator.n_free == 8 and kv.n_free_unreserved == 8


def test_cache_grow_to_window(smoke_model):
    """Window-sized growth: one allocator transaction covers a whole fused
    decode window, stays inside the reservation, and close returns all."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=16, dtype=jnp.float32)
    seq = kv.open_sequence(prompt_tokens=20, total_tokens=60)   # 2 now, 4 rsv
    assert len(seq.blocks) == 2 and seq.reserved == 4
    seq.length = 20
    # an 8-step window writes positions 20..27 -> still inside block 2
    assert kv.grow_to(seq, 28) == 0
    # a window reaching position 47 needs block 3; position 48 needs block 4
    assert kv.grow_to(seq, 48) == 1
    assert kv.grow_to(seq, 60) == 1
    assert len(seq.blocks) == 4 and kv.n_free_unreserved == 8 - 4
    kv.close_sequence(seq)
    assert kv.allocator.n_free == 8 and kv.n_free_unreserved == 8


def test_cache_rejects_oversized_request(smoke_model):
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=5, block_size=16,
                      max_blocks_per_seq=3, dtype=jnp.float32)
    assert not kv.can_admit(4 * 16)         # exceeds per-seq table
    with pytest.raises(OutOfBlocks):
        kv.open_sequence(prompt_tokens=64, total_tokens=64)


# ----------------------------------------------- truncate_to (spec rollback) --

def test_truncate_to_frees_block_granular(smoke_model):
    """Rollback keeps exactly the blocks covering the accepted prefix: whole
    blocks past it return to the free list, a partially-filled tail block
    stays, freed table slots re-point at the null block."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=16, dtype=jnp.float32)
    seq = kv.open_sequence(prompt_tokens=20, total_tokens=112)  # 2 now, 7 rsv
    seq.length = 20
    kv.grow_to(seq, 80)
    assert len(seq.blocks) == 5
    seq.length = 80
    assert kv.truncate_to(seq, 40) == 2          # keep ceil(40/16) = 3
    assert len(seq.blocks) == 3 and seq.length == 40
    assert (seq.table[3:] == 0).all()            # freed slots -> null block
    assert kv.truncate_to(seq, 33) == 0          # tail block only partially
    assert len(seq.blocks) == 3                  # filled: kept, not freed
    # reservation preserved: re-growth to the full admitted budget succeeds
    kv.grow_to(seq, 112)
    assert len(seq.blocks) == 7
    kv.close_sequence(seq)
    kv.assert_drained()


def test_truncate_to_reservation_accounting(smoke_model):
    """Freed blocks stay inside the admission reservation: the free list
    grows (in-flight growth of OTHER admitted requests can consume them)
    but new admissions still see them as promised."""
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=9, block_size=16, dtype=jnp.float32)
    seq = kv.open_sequence(prompt_tokens=48, total_tokens=128)  # 3 now, 8 rsv
    seq.length = 48
    assert kv.n_free_unreserved == 0 and not kv.can_admit(16)
    kv.grow_to(seq, 128)
    assert kv.allocator.n_free == 0
    assert kv.truncate_to(seq, 48) == 5
    assert kv.allocator.n_free == 5              # physically free again...
    assert kv.n_free_unreserved == 0             # ...but still promised
    assert not kv.can_admit(16)
    kv.close_sequence(seq)
    kv.assert_drained()
    assert kv.can_admit(8 * 16)


def test_truncate_rollback_storm_invariants(smoke_model):
    """Seeded grow/rollback storm over interleaved sequences: allocator
    invariants hold after every operation and the pool fully drains."""
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(4)
    kv = PagedKVCache(cfg, num_blocks=17, block_size=8, dtype=jnp.float32)
    seqs = []
    for _ in range(3):
        total = int(rng.integers(16, 40))
        seqs.append((kv.open_sequence(prompt_tokens=8, total_tokens=total),
                     total))
    committed = [8, 8, 8]
    for _ in range(60):
        i = int(rng.integers(len(seqs)))
        seq, total = seqs[i]
        if rng.random() < 0.5:                   # speculate: overgrow
            target = int(rng.integers(committed[i], total + 1))
            kv.grow_to(seq, target)
        else:                                    # verify: accept a prefix,
            accepted = int(rng.integers(committed[i],
                                        len(seq.blocks) * 8 + 1))
            accepted = min(accepted, total)
            kv.truncate_to(seq, accepted)        # roll back the rest
            committed[i] = max(committed[i], min(accepted,
                                                 len(seq.blocks) * 8))
        kv.allocator.check()
        assert kv._reserved_unheld >= 0
        assert len(seq.blocks) <= seq.reserved
    for seq, _ in seqs:
        kv.close_sequence(seq)
    kv.assert_drained()


# ----------------------------------------------------- numerics exactness --

def test_paged_single_request_matches_dense(smoke_model):
    """paged_prefill + paged_decode_step == dense prefill/decode, greedy."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(1)
    for S in (5, 16, 37):                   # below/at/above block boundary
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, S), jnp.int32)
        n = 6
        ref = _ref_generate(model, params, prompt, n)

        BS, NBmax = 16, 8
        pool = model.init_paged_cache(num_blocks=9, block_size=BS,
                                      dtype=jnp.float32)
        table = np.zeros((NBmax,), np.int32)
        nblk = -(-S // BS)
        table[:nblk] = np.arange(1, nblk + 1)
        logits, pool = model.paged_prefill(
            params, prompt[None], pool, block_table=jnp.asarray(table)[None])
        out = [int(jnp.argmax(logits[0, -1]))]
        length = S
        for _ in range(n - 1):
            if length >= nblk * BS:
                table[nblk] = nblk + 1
                nblk += 1
            logits, pool = model.paged_decode_step(
                params, jnp.asarray([[out[-1]]], jnp.int32), pool,
                block_tables=jnp.asarray(table)[None],
                lengths=jnp.asarray([length], jnp.int32))
            out.append(int(jnp.argmax(logits[0, -1])))
            length += 1
        assert out == ref, S


def test_paged_batcher_matches_sequential(smoke_model):
    """Mixed-length requests through the paged batcher == per-request
    sequential decode (block recycling across admissions included: 6
    requests through a pool that fits ~3)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (37, 75, 20, 130, 9, 50)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    pb = PagedBatcher(cfg, params, num_blocks=13, block_size=32,
                      decode_width=3, buckets=(32, 64),
                      cache_dtype=jnp.float32)
    pb.run(reqs)
    for r in reqs:
        assert r.done
        assert r.output == _ref_generate(model, params,
                                         jnp.asarray(r.prompt), 5)
    pb.kv.allocator.check()
    assert pb.kv.allocator.n_free == pb.kv.num_blocks - 1


def test_single_token_requests_match_dense(smoke_model):
    """max_new_tokens=1 is satisfied at prefill: both batchers must emit
    exactly one token (the dense batcher used to overproduce a second)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (12, 30)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=1)
                for i, p in enumerate(prompts)]

    dense = ContinuousBatcher(cfg, params, max_batch=2, max_len=128,
                              buckets=(32, 64))
    dense.cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        dense.cache)
    reqs_d = dense.run(reqs())
    paged = PagedBatcher(cfg, params, num_blocks=9, block_size=16,
                         decode_width=2, buckets=(32, 64),
                         cache_dtype=jnp.float32)
    reqs_p = paged.run(reqs())
    for d, p, prompt in zip(reqs_d, reqs_p, prompts):
        ref = _ref_generate(model, params, jnp.asarray(prompt), 1)
        assert d.output == p.output == ref
        assert d.done and p.done


def test_paged_batcher_rejects_impossible_request(smoke_model):
    """A request that can NEVER fit the pool fails loudly at admission
    instead of being silently dropped after the tick budget."""
    cfg, _, params = smoke_model
    pb = PagedBatcher(cfg, params, num_blocks=2, block_size=32,
                      decode_width=2, cache_dtype=jnp.float32)
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 60
                                             ).astype(np.int32),
                  max_new_tokens=4)
    with pytest.raises(ValueError, match="can never supply"):
        pb.run([req])


# ----------------------------------------------- equal-memory concurrency --

def test_paged_beats_dense_concurrency_at_equal_memory(smoke_model):
    """The acceptance property: with the same token budget, block-granular
    admission sustains strictly more concurrent requests than dense slots,
    with identical greedy outputs."""
    cfg, model, params = smoke_model
    MAX_LEN, BS = 128, 16
    pool_tokens = 2 * MAX_LEN               # dense: exactly 2 slots

    def requests():
        rng = np.random.default_rng(3)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, s
                                            ).astype(np.int32),
                        max_new_tokens=4)
                for i, s in enumerate((20, 33, 17, 40, 25))]

    dense = ContinuousBatcher(cfg, params, max_batch=pool_tokens // MAX_LEN,
                              max_len=MAX_LEN, buckets=(32, 64))
    dense.cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        dense.cache)
    reqs_d = dense.run(requests())

    paged = PagedBatcher(cfg, params, num_blocks=pool_tokens // BS,
                         block_size=BS, decode_width=5, buckets=(32, 64),
                         cache_dtype=jnp.float32)
    reqs_p = paged.run(requests())

    assert all(d.output == p.output for d, p in zip(reqs_d, reqs_p))
    assert paged.peak_active > dense.peak_active
