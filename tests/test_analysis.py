"""repolint (repro.analysis): per-rule fixtures, pragma/baseline workflow,
CLI exit codes, and the live-tree-clean self-check.

Fixture violations live in files written to tmp trees, never in this file
itself — the live-tree self-check walks tests/ too.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.core import (Baseline, load_module, parse_pragmas,
                                 run_repolint)
from repro.analysis.schema import SchemaConfig, StatsSource, \
    check_schema_contract

REPO = Path(__file__).resolve().parents[1]
AST_RULES = ("use-after-donate", "determinism", "jit-hygiene", "host-sync")


def make_tree(tmp_path: Path, files: dict) -> Path:
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def lint(tmp_path, files, rules=AST_RULES):
    return run_repolint(make_tree(tmp_path, files), rules=rules)


def rules_hit(report):
    return sorted({f.rule for f in report.findings})


# ------------------------------------------------------------ determinism --

def test_determinism_true_positives(tmp_path):
    rep = lint(tmp_path, {"src/a.py": """\
        import time
        import random
        from time import sleep as zz
        import numpy as np
        import datetime

        def f():
            t = time.time()
            zz(0.1)
            r = random.random()
            np.random.seed(0)
            d = datetime.datetime.now()
            return t, r, d
    """}, rules=("determinism",))
    assert len(rep.findings) == 5
    assert rules_hit(rep) == ["determinism"]
    lines = {f.line for f in rep.findings}
    assert lines == {8, 9, 10, 11, 12}


def test_determinism_allowlists(tmp_path):
    rep = lint(tmp_path, {
        # telemetry.py IS the clock: monotonic allowed there, only there
        "src/repro/serving/telemetry.py": """\
            import time
            def now():
                return time.monotonic()
        """,
        # benchmarks measure wall time: perf_counter allowed, sleep not
        "benchmarks/bench_x.py": """\
            import time
            def bench():
                return time.perf_counter()
        """,
        # seeded generators are the sanctioned RNG
        "src/b.py": """\
            import numpy as np
            def g():
                return np.random.default_rng(0).normal()
        """,
    }, rules=("determinism",))
    assert rep.findings == []


def test_determinism_monotonic_banned_elsewhere(tmp_path):
    rep = lint(tmp_path, {"src/c.py": """\
        import time
        def f():
            return time.monotonic()
    """}, rules=("determinism",))
    assert len(rep.findings) == 1


# ------------------------------------------------------- use-after-donate --

def test_use_after_donate_true_positive(tmp_path):
    rep = lint(tmp_path, {"src/d.py": """\
        import jax

        class Sched:
            def __init__(self, fn):
                self._decode = jax.jit(fn, donate_argnums=(1,))

            def step(self, params):
                logits, new_pool = self._decode(params, self.pool)
                return logits, self.pool.shape   # read of donated buffer
    """}, rules=("use-after-donate",))
    assert len(rep.findings) == 1
    f = rep.findings[0]
    assert f.rule == "use-after-donate" and f.line == 9
    assert "self.pool" in f.message


def test_use_after_donate_rebind_is_clean(tmp_path):
    rep = lint(tmp_path, {"src/e.py": """\
        import jax

        class Sched:
            def __init__(self, fn):
                self._decode = jax.jit(fn, donate_argnums=(1,))

            def step(self, params):
                logits, self.pool = self._decode(params, self.pool)
                return logits, self.pool         # rebound: fine
    """}, rules=("use-after-donate",))
    assert rep.findings == []


def test_use_after_donate_loop_carried(tmp_path):
    # donate in iteration N, read (as the call argument) in iteration N+1
    # without a rebind — only visible on the second pass over the loop body
    rep = lint(tmp_path, {"src/f.py": """\
        import jax

        @jax.jit
        def _noop(c):
            return c

        step = jax.jit(_noop, donate_argnums=(0,))

        def run(cache, n):
            out = []
            for _ in range(n):
                logits = step(cache)   # cache never rebound
                out.append(logits)
            return out
    """}, rules=("use-after-donate",))
    assert len(rep.findings) >= 1
    assert all(f.rule == "use-after-donate" for f in rep.findings)


def test_use_after_donate_branch_return_is_clean(tmp_path):
    # the donating call's branch returns: the fall-through path never saw
    # the donation (the core/sync.py paged_decode_window shape)
    rep = lint(tmp_path, {"src/g.py": """\
        import jax

        win = jax.jit(lambda p: p, donate_argnums=(0,))
        mixed = jax.jit(lambda p, q: p, donate_argnums=(0,))

        def dispatch(pool, is_plain, extra):
            if is_plain:
                return win(pool)
            return mixed(pool, extra)
    """}, rules=("use-after-donate",))
    assert rep.findings == []


# ------------------------------------------------------------ jit-hygiene --

def test_jit_hygiene_loop_and_hot_fn(tmp_path):
    rep = lint(tmp_path, {"src/h.py": """\
        import jax

        def run(fns, x):
            for fn in fns:
                y = jax.jit(fn)(x)       # fresh wrapper every iteration
            return y

        class Engine:
            def step(self, x):
                return jax.jit(self.fwd)(x)   # re-jit per step
    """}, rules=("jit-hygiene",))
    assert len(rep.findings) == 2
    assert {f.line for f in rep.findings} == {5, 10}


def test_jit_hygiene_builders_and_tests_exempt(tmp_path):
    rep = lint(tmp_path, {
        "src/i.py": """\
            import jax

            def make_train_step(fn):
                return jax.jit(fn, donate_argnums=(0,))   # built once: fine

            def build_serve_step(fn):
                return jax.jit(fn)
        """,
        "tests/test_i.py": """\
            import jax

            def test_decode_step():
                out = jax.jit(lambda x: x)(1)
        """,
    }, rules=("jit-hygiene",))
    assert rep.findings == []


def test_jit_hygiene_pool_carrying_needs_donation(tmp_path):
    files = {"src/j.py": """\
        import jax

        def paged_decode_step(params, tok, pool):
            return tok, pool

        f = jax.jit(paged_decode_step)
    """}
    rep = lint(tmp_path, files, rules=("jit-hygiene",))
    assert len(rep.findings) == 1
    assert "donate_argnums" in rep.findings[0].message


def test_jit_hygiene_pool_carrying_outside_src_is_clean(tmp_path):
    # same snippet under tests/: jitting once without donation is harmless
    rep = lint(tmp_path, {"tests/j2.py": """\
        import jax

        def paged_decode_step(params, tok, pool):
            return tok, pool

        f = jax.jit(paged_decode_step)
    """}, rules=("jit-hygiene",))
    assert rep.findings == []


# -------------------------------------------------------------- host-sync --

def test_host_sync_block_until_ready_placement(tmp_path):
    rep = lint(tmp_path, {
        "src/k.py": """\
            import jax
            def f(x):
                jax.block_until_ready(x)
        """,
        "src/repro/core/sync.py": """\
            import jax
            def fence(x):
                jax.block_until_ready(x)   # the sanctioned site
        """,
        "benchmarks/bench_k.py": """\
            import jax
            def bench(x):
                jax.block_until_ready(x)
        """,
    }, rules=("host-sync",))
    assert len(rep.findings) == 1
    assert rep.findings[0].path == "src/k.py"


def test_host_sync_traced_body_sinks(tmp_path):
    rep = lint(tmp_path, {"src/l.py": """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            y = x + 1
            if y > 0:              # implicit bool() on traced value
                return y
            n = np.asarray(y)      # host pull inside the trace
            return y.item()        # and another
    """}, rules=("host-sync",))
    assert len(rep.findings) == 3


def test_host_sync_shape_branching_is_static(tmp_path):
    rep = lint(tmp_path, {"src/m.py": """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def pad(q):
            D = q.shape[-1]
            if D % 128:            # trace-time static: fine
                q = jnp.pad(q, ((0, 0), (0, 128 - D % 128)))
            assert q.ndim == 2     # also static
            return q
    """}, rules=("host-sync",))
    assert rep.findings == []


def test_host_sync_scan_body_checked(tmp_path):
    rep = lint(tmp_path, {"src/n.py": """\
        import jax

        def body(carry, x):
            if carry:              # traced: lax.scan operand
                x = x + 1
            return carry, x

        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """}, rules=("host-sync",))
    assert len(rep.findings) == 1


# --------------------------------------------------------- schema-contract --

_SCHEMA_CFG = SchemaConfig(
    trace_relpath="src/pkg/trace.py",
    docs_relpath="docs/obs.md",
    sources=(StatsSource("src/pkg/sched.py", "B", "stats", "b",
                         merged=False),),
    snapshot_keys=())

_SCHEMA_TRACE = """\
    STATS_COUNTER_KEYS = ("hits",)
    STATS_GAUGE_KEYS = ("depth",)
"""
_SCHEMA_SCHED = """\
    class B:
        def stats(self):
            return {"hits": self.hits, "depth": self.d}

        def tick(self):
            self.tracer.count("hits")
            self.tracer.gauge("depth", 1)
"""
_SCHEMA_DOCS = """\
    ## Metrics exposition

    - counters: `hits`; plus `dispatches{kind=...}`.
    - gauges: `depth`.
"""


def _schema_findings(tmp_path, files):
    root = make_tree(tmp_path, files)
    modules = [m for m in (load_module(p, root)
                           for p in sorted(root.rglob("*.py"))) if m]
    return check_schema_contract(root, modules, config=_SCHEMA_CFG)


def test_schema_contract_consistent_tree_is_clean(tmp_path):
    assert _schema_findings(tmp_path, {
        "src/pkg/trace.py": _SCHEMA_TRACE,
        "src/pkg/sched.py": _SCHEMA_SCHED,
        "docs/obs.md": _SCHEMA_DOCS}) == []


def test_schema_contract_catches_unregistered_counter(tmp_path):
    sched = _SCHEMA_SCHED.replace(
        'self.tracer.count("hits")',
        'self.tracer.count("hits")\n'
        '            self.tracer.count("misses")')
    found = _schema_findings(tmp_path, {
        "src/pkg/trace.py": _SCHEMA_TRACE,
        "src/pkg/sched.py": sched,
        "docs/obs.md": _SCHEMA_DOCS})
    assert any("misses" in f.message and "STATS_COUNTER_KEYS" in f.message
               for f in found)


def test_schema_contract_catches_stats_key_without_producer(tmp_path):
    trace = _SCHEMA_TRACE.replace('("hits",)', '("hits", "orphan")')
    found = _schema_findings(tmp_path, {
        "src/pkg/trace.py": trace,
        "src/pkg/sched.py": _SCHEMA_SCHED,
        "docs/obs.md": _SCHEMA_DOCS})
    msgs = "\n".join(f.message for f in found)
    assert "orphan" in msgs and "stats()" in msgs


def test_schema_contract_catches_docs_drift(tmp_path):
    docs = _SCHEMA_DOCS.replace("`hits`; plus", "`stale_name`; plus")
    found = _schema_findings(tmp_path, {
        "src/pkg/trace.py": _SCHEMA_TRACE,
        "src/pkg/sched.py": _SCHEMA_SCHED,
        "docs/obs.md": docs})
    msgs = "\n".join(f.message for f in found)
    assert "hits" in msgs and "stale_name" in msgs


def test_schema_contract_collision_between_merged_groups(tmp_path):
    cfg = SchemaConfig(
        trace_relpath="src/pkg/trace.py", docs_relpath="docs/obs.md",
        sources=(StatsSource("src/pkg/sched.py", "B", "stats", "b",
                             merged=True),
                 StatsSource("src/pkg/pool.py", "P", "pool_stats", "p",
                             merged=True)),
        snapshot_keys=())
    root = make_tree(tmp_path, {
        "src/pkg/trace.py": _SCHEMA_TRACE,
        "src/pkg/sched.py": _SCHEMA_SCHED,
        "src/pkg/pool.py": """\
            class P:
                def pool_stats(self):
                    return {"hits": 0}     # collides with B.stats
        """,
        "docs/obs.md": _SCHEMA_DOCS})
    modules = [m for m in (load_module(p, root)
                           for p in sorted(root.rglob("*.py"))) if m]
    found = check_schema_contract(root, modules, config=cfg)
    assert any("collides" in f.message for f in found)


# --------------------------------------------------------- pragma workflow --

def test_pragma_suppresses_with_reason(tmp_path):
    rep = lint(tmp_path, {"src/p.py": """\
        import time
        def f():
            return time.time()  # repolint: disable=determinism -- fixture
    """}, rules=("determinism",))
    assert rep.findings == [] and rep.suppressed == 1


def test_pragma_without_reason_is_a_finding(tmp_path):
    rep = lint(tmp_path, {"src/q.py": """\
        import time
        def f():
            return time.time()  # repolint: disable=determinism
    """}, rules=("determinism",))
    # suppression still applies, but the bare pragma itself is flagged
    assert rep.suppressed == 1
    assert [f.rule for f in rep.findings] == ["pragma"]
    assert "no reason" in rep.findings[0].message


def test_unused_and_unknown_pragmas_are_findings(tmp_path):
    rep = lint(tmp_path, {"src/r.py": """\
        x = 1  # repolint: disable=determinism -- suppresses nothing
        y = 2  # repolint: disable=no-such-rule -- typo'd rule name
    """}, rules=("determinism",))
    msgs = sorted(f.message for f in rep.findings)
    assert len(msgs) == 2
    assert any("unused pragma" in m for m in msgs)
    assert any("unknown rule" in m for m in msgs)


def test_pragma_parser():
    pragmas = parse_pragmas([
        "x = 1  # repolint: disable=determinism,host-sync -- two rules",
        "y = 2",
    ])
    assert list(pragmas) == [1]
    assert pragmas[1].rules == ("determinism", "host-sync")
    assert pragmas[1].reason == "two rules"


# ------------------------------------------------------- baseline workflow --

def test_baseline_round_trip(tmp_path):
    files = {"src/s.py": """\
        import time
        def f():
            return time.time()
    """}
    root = make_tree(tmp_path, files)
    rep = run_repolint(root, rules=("determinism",))
    assert len(rep.new) == 1

    bpath = root / "baseline.json"
    Baseline.from_findings(rep.findings).save(bpath)
    rep2 = run_repolint(root, rules=("determinism",),
                        baseline=Baseline.load(bpath))
    assert rep2.ok and rep2.new == [] and rep2.stale == []

    # fingerprints are line-number independent: edits above don't churn
    (root / "src/s.py").write_text(
        "import time\n\n\ndef f():\n    return time.time()\n")
    rep3 = run_repolint(root, rules=("determinism",),
                        baseline=Baseline.load(bpath))
    assert rep3.ok

    # fixing the finding makes the baseline entry stale -> not ok
    (root / "src/s.py").write_text("def f():\n    return 0\n")
    rep4 = run_repolint(root, rules=("determinism",),
                        baseline=Baseline.load(bpath))
    assert not rep4.ok and len(rep4.stale) == 1


# ------------------------------------------------------------------- CLI ---

def _cli(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "repolint.py"), *args],
        capture_output=True, text=True)


def test_cli_exit_codes(tmp_path):
    root = make_tree(tmp_path, {"src/t.py": "def f():\n    return 0\n"})
    clean = _cli("--root", str(root), "--rules", "determinism", "--check")
    assert clean.returncode == 0, clean.stdout + clean.stderr

    (root / "src/t.py").write_text("import time\nT = time.time()\n")
    dirty = _cli("--root", str(root), "--rules", "determinism", "--check")
    assert dirty.returncode == 1
    assert "[determinism]" in dirty.stdout and "FAIL" in dirty.stdout


def test_cli_list_rules():
    out = _cli("--list-rules")
    assert out.returncode == 0
    for rule in ("use-after-donate", "determinism", "jit-hygiene",
                 "host-sync", "schema-contract"):
        assert rule in out.stdout


# --------------------------------------------------------- live-tree gate --

def test_live_tree_is_clean():
    """The committed tree has zero findings and an empty baseline — every
    grandfathered issue was fixed or pragma'd with a reason."""
    report = run_repolint(REPO)
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings)
    assert report.ok
    assert report.n_files > 100   # really walked the tree

    baseline = Baseline.load(REPO / ".repolint-baseline.json")
    assert sum(baseline.counts.values()) == 0
