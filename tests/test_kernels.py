"""Per-kernel validation: seeded shape/dtype sweeps, always against the
pure-jnp ref.py oracle (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hetero_matmul.ops import (mxu_matmul, mxu_quant_matmul,
                                             quantize_weight)
from repro.kernels.hetero_matmul.ref import matmul_ref, quant_matmul_ref

RNG = jax.random.PRNGKey(0)


def _rel(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
                 / (jnp.max(jnp.abs(b.astype(jnp.float32))) + 1e-9))


# ------------------------------------------------------------ hetero matmul --

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-6), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 512),
                                 (384, 128, 256), (128, 512, 128)])
@pytest.mark.parametrize("stationary", ["output", "weight"])
def test_mxu_matmul_sweep(mkn, dtype, tol, stationary):
    M, K, N = mkn
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), dtype)
    w = jax.random.normal(k2, (K, N), dtype)
    y = mxu_matmul(x, w, stationary=stationary)
    assert _rel(y, matmul_ref(x, w)) < tol


@pytest.mark.parametrize("mkn", [(128, 256, 128), (256, 128, 384)])
def test_quant_matmul_sweep(mkn):
    M, K, N = mkn
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    wq, s = quantize_weight(w)
    assert _rel(mxu_quant_matmul(x, wq, s), quant_matmul_ref(x, wq, s)) < 2e-6
    # int8 quantization itself stays within per-channel bound
    assert _rel(quant_matmul_ref(x, wq, s), matmul_ref(x, w)) < 0.05


@pytest.mark.parametrize("tm,tk,tn,stationary", [
    (1, 1, 1, "output"), (2, 3, 1, "weight"), (3, 1, 2, "output"),
    (1, 2, 3, "weight"), (2, 2, 2, "output"), (3, 3, 3, "weight")])
def test_mxu_matmul_property(tm, tk, tn, stationary):
    """Any tile-aligned shape agrees with the oracle (both grid orders)."""
    M, K, N = tm * 128, tk * 128, tn * 128
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    assert _rel(mxu_matmul(x, w, stationary=stationary),
                matmul_ref(x, w)) < 2e-6


# ---------------------------------------------------------- flash attention --

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-6), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("cfg", [
    dict(B=2, S=256, Hq=8, Hkv=2, D=64, bq=64, bk=64, causal=True),
    dict(B=1, S=512, Hq=4, Hkv=4, D=128, bq=128, bk=128, causal=True),
    dict(B=2, S=128, Hq=6, Hkv=2, D=80, bq=32, bk=64, causal=False),
    dict(B=1, S=256, Hq=8, Hkv=1, D=64, bq=128, bk=64, causal=True),
])
def test_flash_attention_sweep(cfg, dtype, tol):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (cfg["B"], cfg["S"], cfg["Hq"], cfg["D"]), dtype)
    k = jax.random.normal(ks[1], (cfg["B"], cfg["S"], cfg["Hkv"], cfg["D"]), dtype)
    v = jax.random.normal(ks[2], (cfg["B"], cfg["S"], cfg["Hkv"], cfg["D"]), dtype)
    o = flash_attention(q, k, v, causal=cfg["causal"], block_q=cfg["bq"],
                        block_k=cfg["bk"])
    assert _rel(o, attention_ref(q, k, v, causal=cfg["causal"])) < tol


@pytest.mark.parametrize("sblocks,g,causal", [
    (1, 1, True), (2, 4, True), (3, 2, False), (4, 1, False), (2, 2, True)])
def test_flash_attention_property(sblocks, g, causal):
    S = sblocks * 64
    Hkv, D = 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, S, Hkv * g, D), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, Hkv, D), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    assert _rel(o, attention_ref(q, k, v, causal=causal)) < 2e-6


# --------------------------------------------------------- decode attention --

@pytest.mark.parametrize("length", [1, 77, 300, 512])
def test_decode_attention_sweep(length):
    B, S, Hq, Hkv, D = 2, 512, 8, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    o = decode_attention(q, kc, vc, length, block_k=128)
    assert _rel(o, decode_attention_ref(q, kc, vc, length)) < 2e-6


@pytest.mark.parametrize("length,bk", [
    (1, 64), (63, 64), (64, 64), (65, 128), (200, 128), (256, 256),
    (129, 256)])
def test_decode_attention_property(length, bk):
    """Valid-prefix masking is exact for any length and block size."""
    B, S, Hq, Hkv, D = 1, 256, 4, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    o = decode_attention(q, kc, vc, length, block_k=bk)
    assert _rel(o, decode_attention_ref(q, kc, vc, length)) < 2e-6


# ---------------------------------------------------------------- ssm scan --

@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_scan_kernel_matches_model_path(chunk):
    from repro.kernels.ssm_scan.ops import ssd_scan
    from repro.models.mamba2 import ssd_chunked
    B, S, nh, hd, N = 2, 128, 4, 64, 64
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y1, s1 = ssd_scan(xh, dt, A, B_, C_, chunk=chunk)
    y2, s2 = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-4


def test_ssd_chunk_kernel_vs_ref():
    from repro.kernels.ssm_scan.kernel import ssd_chunk_pallas
    from repro.kernels.ssm_scan.ref import ssd_chunk_ref
    B, L, nh, hd, N = 2, 64, 3, 64, 64
    ks = jax.random.split(RNG, 5)
    xb = jax.random.normal(ks[0], (B, L, nh, hd))
    B_ = jax.random.normal(ks[1], (B, L, N)) * 0.5
    C_ = jax.random.normal(ks[2], (B, L, N)) * 0.5
    seg = -jnp.cumsum(jnp.abs(jax.random.normal(ks[3], (B, L, nh))) * 0.1, 1)
    S_prev = jax.random.normal(ks[4], (B, nh, hd, N)) * 0.3
    y1, s1 = ssd_chunk_pallas(xb, B_, C_, seg, S_prev)
    y2, s2 = ssd_chunk_ref(xb, B_, C_, seg, S_prev)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-4


# ------------------------------------------------------------------ W4A16 --

@pytest.mark.parametrize("mkn", [(128, 256, 128), (256, 128, 384)])
def test_q4_matmul_w4a16(mkn):
    """The paper's W4A16 format: int4-packed weights, fp dequant in VMEM."""
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 mxu_q4_matmul,
                                                 quantize_weight_int4)
    M, K, N = mkn
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    wq4, s = quantize_weight_int4(w)
    y = mxu_q4_matmul(x, wq4, s)
    ref = x @ dequant_int4_ref(wq4, s)
    assert _rel(y, ref) < 2e-6           # kernel == dequant oracle (exact)
    assert _rel(ref, x @ w) < 0.15       # int4 quantization error bound
