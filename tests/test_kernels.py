"""Kernel-specific PROPERTY tests (grid orders, GQA ratios, block sizes,
model-path equivalence). Plain dtype/shape parity — including ragged-M and
odd-K edge cases — lives in the unified conformance harness
(test_kernel_conformance.py, one shared parameterization for all four
kernel packages against their ref.py oracles)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import rel_err

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hetero_matmul.ops import mxu_matmul
from repro.kernels.hetero_matmul.ref import matmul_ref

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("tm,tk,tn,stationary", [
    (1, 1, 1, "output"), (2, 3, 1, "weight"), (3, 1, 2, "output"),
    (1, 2, 3, "weight"), (2, 2, 2, "output"), (3, 3, 3, "weight")])
def test_mxu_matmul_property(tm, tk, tn, stationary):
    """Any tile-aligned shape agrees with the oracle (both grid orders)."""
    M, K, N = tm * 128, tk * 128, tn * 128
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    assert rel_err(mxu_matmul(x, w, stationary=stationary),
                   matmul_ref(x, w)) < 2e-6


@pytest.mark.parametrize("sblocks,g,causal", [
    (1, 1, True), (2, 4, True), (3, 2, False), (4, 1, False), (2, 2, True)])
def test_flash_attention_property(sblocks, g, causal):
    """Any GQA group size / block count / causality matches the oracle."""
    S = sblocks * 64
    Hkv, D = 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, S, Hkv * g, D), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, Hkv, D), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    assert rel_err(o, attention_ref(q, k, v, causal=causal)) < 2e-6


@pytest.mark.parametrize("length,bk", [
    (1, 64), (63, 64), (64, 64), (65, 128), (200, 128), (256, 256),
    (129, 256)])
def test_decode_attention_property(length, bk):
    """Valid-prefix masking is exact for any length and block size."""
    B, S, Hq, Hkv, D = 1, 256, 4, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    o = decode_attention(q, kc, vc, length, block_k=bk)
    assert rel_err(o, decode_attention_ref(q, kc, vc, length)) < 2e-6


@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_scan_kernel_matches_model_path(chunk):
    """The full Pallas SSD scan equals the model's chunked-recurrence path
    (the integration contract the zamba2 cells rely on)."""
    from repro.kernels.ssm_scan.ops import ssd_scan
    from repro.models.mamba2 import ssd_chunked
    B, S, nh, hd, N = 2, 128, 4, 64, 64
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y1, s1 = ssd_scan(xh, dt, A, B_, C_, chunk=chunk)
    y2, s2 = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-4
