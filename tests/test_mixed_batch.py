"""Stage-parallel mixed batching (prefill⊕decode fusion).

Contracts under test, mirroring the serving invariant (fusion is an
execution-schedule change, never a numerics change):

  * ``transformer.mixed_step`` — one dispatch running decode lanes + a
    prefill chunk — is BIT-exact against running the two stages
    sequentially on the same pool (disjoint block tables);
  * the chunk-carrying ``paged_decode_window`` emits the same decode
    tokens as a plain window and the same chunk logits as a standalone
    prefill;
  * the mixed-batch ``PagedBatcher`` generates token-identical greedy
    outputs while issuing strictly fewer host dispatches per finished
    token than admit-then-decode, never stalling decode during admission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.scheduler import PagedBatcher, Request

W, BS, NBMAX = 2, 16, 8

# smoke_model: session-scoped fixture from conftest.py


def _pool_and_tables(model):
    """A pool with two decode lanes (blocks 1-2, 3-4) and one admitting
    sequence (blocks 5-6) — disjoint by construction, like the allocator
    guarantees."""
    pool = model.init_paged_cache(num_blocks=9, block_size=BS,
                                  dtype=jnp.float32)
    dec_tables = np.zeros((W, NBMAX), np.int32)
    dec_tables[0, :2] = [1, 2]
    dec_tables[1, :2] = [3, 4]
    pre_table = np.zeros((1, NBMAX), np.int32)
    pre_table[0, :2] = [5, 6]
    return pool, jnp.asarray(dec_tables), jnp.asarray(pre_table)


def _warm_pool(model, params, pool, dec_tables, rng, lengths):
    """Prefill each decode lane's history so the fused step reads real KV."""
    for i, ln in enumerate(lengths):
        toks = rng.integers(0, model.cfg.vocab_size, ln).astype(np.int32)
        _, pool = model.paged_prefill(params, jnp.asarray(toks)[None], pool,
                                      block_table=dec_tables[i:i + 1])
    return pool


def test_mixed_step_bit_exact_vs_sequential(smoke_model):
    """ONE fused dispatch == decode step then prefill chunk, bit for bit:
    decode logits, chunk logits AND the shared pool write."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(0)
    pool, dec_tables, pre_table = _pool_and_tables(model)
    lengths = np.asarray([13, 7], np.int32)
    pool = _warm_pool(model, params, pool, dec_tables, rng, lengths)

    last = jnp.asarray(rng.integers(0, cfg.vocab_size, (W, 1)), jnp.int32)
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 21)), jnp.int32)

    d_logits, pool_a = model.paged_decode_step(
        params, last, pool, block_tables=dec_tables,
        lengths=jnp.asarray(lengths))
    p_logits, pool_a = model.paged_prefill(
        params, chunk, pool_a, block_table=pre_table)

    dm, pm, pool_b = model.mixed_step(
        params, last, chunk, pool, decode_tables=dec_tables,
        decode_lengths=jnp.asarray(lengths), prefill_table=pre_table)

    assert np.array_equal(np.asarray(dm), np.asarray(d_logits))
    assert np.array_equal(np.asarray(pm), np.asarray(p_logits))
    for t in ("k", "v"):
        assert np.array_equal(np.asarray(pool_b[t]), np.asarray(pool_a[t]))


def test_window_carries_prefill_chunk(smoke_model):
    """A chunk-carrying fused window: decode tokens identical to the plain
    window, chunk logits identical to a standalone prefill — one dispatch
    instead of two."""
    from repro.core.sync import paged_decode_window
    cfg, model, params = smoke_model
    rng = np.random.default_rng(1)
    pool, dec_tables, pre_table = _pool_and_tables(model)
    lengths = np.asarray([9, 17], np.int32)
    pool = _warm_pool(model, params, pool, dec_tables, rng, lengths)
    last = jnp.asarray(rng.integers(0, cfg.vocab_size, (W, 1)), jnp.int32)
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 14)), jnp.int32)
    remaining = jnp.asarray([3, 2], jnp.int32)
    key = jax.random.PRNGKey(3)

    def pool_copy():
        return {t: jnp.array(pool[t]) for t in ("k", "v")}

    toks_a, valid_a, pool_plain, _, _ = paged_decode_window(
        model, params, last, pool_copy(), dec_tables,
        jnp.asarray(lengths), remaining, key, 3)
    p_logits, _ = model.paged_prefill(params, chunk, pool_copy(),
                                      block_table=pre_table)

    toks_b, valid_b, pre_logits, _, _, _ = paged_decode_window(
        model, params, last, pool_copy(), dec_tables,
        jnp.asarray(lengths), remaining, key, 3,
        prefill_tokens=chunk, prefill_table=pre_table)

    assert np.array_equal(np.asarray(toks_a), np.asarray(toks_b))
    assert np.array_equal(np.asarray(valid_a), np.asarray(valid_b))
    assert np.array_equal(np.asarray(pre_logits), np.asarray(p_logits))


def _staggered_run(cfg, params, prompts, budgets, gap=2, **kw):
    """Submit one request every ``gap`` ticks so later admissions happen
    while earlier requests decode (the fusion regime)."""
    max_len = max(len(p) for p in prompts) + max(budgets)
    n = len(prompts)
    pb = PagedBatcher(cfg, params,
                      num_blocks=1 + n * -(-max_len // BS), block_size=BS,
                      max_blocks_per_seq=-(-max_len // BS),
                      decode_width=n, buckets=(32, 64),
                      cache_dtype=jnp.float32, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, budgets))]
    pending = list(reqs)
    tick = 0
    while pending or pb.busy:
        if pending and tick % gap == 0:
            pb.submit(pending.pop(0))
        pb.step()
        tick += 1
        assert tick < 1000
    pb.kv.assert_drained()
    return reqs, pb


@pytest.mark.parametrize("sync,kw", [("host", {}),
                                     ("device", {"window": 3})])
def test_mixed_batcher_fewer_dispatches_token_exact(smoke_model, sync, kw):
    """The acceptance property end to end: under staggered arrivals the
    mixed arm emits identical greedy streams with strictly fewer host
    dispatches per finished token, admission chunks actually fuse, and no
    standalone prefill dispatch happens while lanes are decoding."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (41, 33, 57, 20)]
    budgets = [9, 7, 8, 6]

    base_reqs, base = _staggered_run(cfg, params, prompts, budgets,
                                     sync=sync, **kw)
    mix_reqs, mix = _staggered_run(cfg, params, prompts, budgets,
                                   sync=sync, mixed_batch=True, **kw)
    for b, m in zip(base_reqs, mix_reqs):
        assert b.output == m.output and b.done and m.done
    tokens = sum(len(r.output) for r in base_reqs)
    assert tokens == sum(len(r.output) for r in mix_reqs)
    assert mix.fused_steps > 0
    assert mix.total_dispatches < base.total_dispatches, \
        (sync, mix.total_dispatches, base.total_dispatches)
    # decode never stalls: both arms decode the same number of steps, and
    # only the FIRST request (empty server) paid standalone prefill
    # dispatches — every later chunk rode a decode dispatch
    assert mix.decode_steps == base.decode_steps == sum(budgets) - len(budgets)
    first_chunks = 2                     # 41 tokens -> chunks (32, 9)
    assert mix.prefill_dispatches == first_chunks
    assert mix.fused_steps == base.prefill_dispatches - first_chunks


def test_mixed_chunk_cap(smoke_model):
    """``max_prefill_chunk_per_step`` bounds the compute fused per step:
    capping at 16 splits a 41-token prompt into ceil(41/16)=3 chunks, all
    token-exact."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (41, 26)]
    budgets = [5, 4]
    base_reqs, _ = _staggered_run(cfg, params, prompts, budgets, sync="host")
    mix_reqs, mix = _staggered_run(cfg, params, prompts, budgets,
                                   sync="host", mixed_batch=True,
                                   max_prefill_chunk_per_step=16)
    for b, m in zip(base_reqs, mix_reqs):
        assert b.output == m.output
    # 41 -> (16, 16, 9), 26 -> (16, 10): 5 chunks total across both paths
    assert mix.prefill_dispatches + mix.fused_steps == 5
