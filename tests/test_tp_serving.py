"""Tensor-parallel paged serving: bit-identical token streams over a mesh.

The ``MeshLayout`` sharding plan (serving/layout.py) splits every weight on
its OUTPUT axis and concatenates shard slices with tiled all-gathers, so TP
is an execution schedule, never a numerics change: greedy token streams
from the TP=2 / TP=4 paged batcher must be BIT-IDENTICAL to the
single-device batcher — across standalone prefill, per-token host-synced
decode, fused decode windows (the shard_mapped step as the scan body),
stage-parallel mixed batching, speculative verify, prefix caching and both
quantized formats (int8 pool slot scales use a global-amax pmax, which is
max-of-maxes exact). Host bookkeeping is device-agnostic: every arm must
drain its pool exactly like the single-device arm.

Runs on the 8 virtual CPU devices conftest.py configures via
``--xla_force_host_platform_device_count``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request
from repro.serving.spec import SpecConfig

BS = 16
N_NEW = 8
PROMPT_LENS = (5, 12, 33)       # straddles block and bucket boundaries


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in PROMPT_LENS]


def _run(cfg, params, mesh=None, **kw):
    """One closed-loop serve through the paged batcher; returns rid->tokens
    and asserts the pool drained (TP must not change host bookkeeping)."""
    b = PagedBatcher(cfg, params, num_blocks=40, block_size=BS,
                     max_blocks_per_seq=4, decode_width=3, buckets=(16, 32),
                     cache_dtype=jnp.float32, mesh=mesh, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=N_NEW)
            for i, p in enumerate(_prompts(cfg))]
    b.run(reqs)
    for r in reqs:
        assert r.done, r.rid
    b.kv.assert_drained()
    assert not b.busy and not b.queue
    return b, {r.rid: tuple(r.output) for r in reqs}


# arm name -> PagedBatcher kwargs; each TP run is compared against a
# single-device run of the SAME arm (quant arms change numerics, so the
# reference must be the quantized single-device batcher)
ARMS = {
    "host": dict(sync="host"),
    "device": dict(sync="device", window=3),
    "mixed": dict(sync="device", window=3, mixed_batch=True),
    "prefix_cache": dict(sync="host", prefix_cache=True),
    "spec_self": dict(sync="host", spec=SpecConfig(k=2)),
    "w4a16_kv_int8": dict(sync="device", window=3, weight_quant="w4a16",
                          kv_quant="int8"),
    "w_int8": dict(sync="host", weight_quant="int8"),
    "kv_int8": dict(sync="host", kv_quant="int8"),
}
SLOW_ARMS = {"w_int8", "kv_int8"}        # formats already covered combined


@pytest.mark.tier1
@pytest.mark.parametrize("arm", [
    a if a not in SLOW_ARMS else pytest.param(a, marks=pytest.mark.slow)
    for a in sorted(ARMS)])
def test_tp2_arms_bit_identical_to_single_device(smoke_model, arm):
    cfg, _, params = smoke_model
    kw = ARMS[arm]
    _, ref = _run(cfg, params, **kw)
    b, tp = _run(cfg, params, mesh=make_host_mesh(1, 2), **kw)
    assert tp == ref, arm
    assert b.stats()["tp"] == 2
    if arm == "spec_self":
        st = b.stats()
        assert st["verify_dispatches"] > 0
        assert 0.0 <= st["acceptance_rate"] <= 1.0
    if arm == "prefix_cache":
        # replay: warm hits must route through the SHARDED pool's CoW path
        b2, tp2 = _run(cfg, params, mesh=make_host_mesh(1, 2), **kw)
        assert tp2 == ref and b2.stats() is not None


@pytest.fixture(scope="module")
def tp4_model():
    """TP=4 needs n_kv_heads % 4 == 0 — the widened-KV smoke variant."""
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32",
                                              n_kv_heads=4)
    model = build_model(cfg)
    return cfg, model.init(jax.random.PRNGKey(7))


@pytest.mark.tier1
@pytest.mark.parametrize("arm", ["host", "device"])
def test_tp4_bit_identical_to_single_device(tp4_model, arm):
    cfg, params = tp4_model
    _, ref = _run(cfg, params, **ARMS[arm])
    b, tp = _run(cfg, params, mesh=make_host_mesh(1, 4), **ARMS[arm])
    assert tp == ref
    assert b.stats()["tp"] == 4


@pytest.mark.tier1
def test_tp_actually_shards_weights_and_pool(smoke_model):
    """Placement is real, not cosmetic: column-sharded weights and the KV
    pool land with a 'model' entry in their sharding spec; norms, embed and
    the int8 scale planes replicate (the docs' shards-vs-replicates table)."""
    cfg, _, params = smoke_model
    mesh = make_host_mesh(1, 2)
    b = PagedBatcher(cfg, params, num_blocks=40, block_size=BS,
                     max_blocks_per_seq=4, decode_width=3, buckets=(16, 32),
                     cache_dtype=jnp.float32, mesh=mesh, kv_quant="int8")

    def spec_of(leaf):
        return tuple(leaf.sharding.spec)

    flat = jax.tree_util.tree_flatten_with_path(b.params)[0]
    by_path = {"/".join(str(k.key) for k in p
                        if isinstance(k, jax.tree_util.DictKey)): v
               for p, v in flat}
    # column-sharded sites carry 'model' on their LAST axis
    for name in ("attn/wq", "attn/wo", "ffn/w_gate", "ffn/w_down"):
        hits = [v for k, v in by_path.items() if k.endswith(name)]
        assert hits, name
        for v in hits:
            assert spec_of(v)[-1] == "model", name
    # embed and norms replicate
    for k, v in by_path.items():
        if k == "embed" or k.endswith("norm") or "norm/" in k:
            assert "model" not in spec_of(v), k
    # pool: KV heads shard (axis 3), int8 slot-scale planes replicate
    assert b.kv.pool["k"].sharding.spec[3] == "model"
    assert "model" not in tuple(b.kv.pool["k_scale"].sharding.spec)


@pytest.mark.tier1
def test_tp_validation_errors(smoke_model):
    cfg, _, params = smoke_model
    kw = dict(num_blocks=40, block_size=BS, decode_width=3,
              buckets=(16, 32), cache_dtype=jnp.float32)
    # n_kv_heads=2 cannot split 4 ways
    with pytest.raises(ValueError, match="n_kv_heads"):
        PagedBatcher(cfg, params, mesh=make_host_mesh(1, 4), **kw)
    # the hetero engine and the mesh are separate axes of the machine
    with pytest.raises(ValueError, match="mutually exclusive"):
        PagedBatcher(cfg, params, mesh=make_host_mesh(1, 2),
                     engine_mode="hetero-tensor", **kw)
    # a mesh without a 'model' axis names no TP width
    with pytest.raises(ValueError, match="model"):
        PagedBatcher(cfg, params, mesh=jax.make_mesh((2,), ("x",)), **kw)
