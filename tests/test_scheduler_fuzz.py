"""Scheduler fuzz: seeded randomized workloads through every serving arm.

For random arrival orders, prompt lengths and token budgets, the six
scheduler arms — dense slots, paged host-sync, paged device-sync (fused
windows), paged mixed-batch (prefill⊕decode fusion), and the two
speculative-decoding arms (host-sync with an INDEPENDENT random-init draft
model exercising zero/partial acceptance + rollback storms; device-sync
self-draft exercising full acceptance and the fused draft scan) — must all
produce GREEDY token streams identical to the sequential single-request
reference, and the paged arms must return every pool block on drain (zero
leaks, ``PagedKVCache.assert_drained``).

Prompt lengths are drawn from a fixed palette so the arms share a bounded
set of compiled chunk graphs (the bucketing contract); arrival order and
budgets are fully random per seed.

A separate prefix-cache arm replays random shared/unshared prompt mixes
(two system prompts, random tails, a second wave over retired blocks) on
both sync modes: warm-path outputs must stay token-identical to the
sequential reference and cache retention must not leak.

Quantized arms (weight-quant int8/w4a16, int8 KV pool, and both together)
run the same workloads against sequential QUANTIZED references — greedy
token identity must survive quantization because every arm dequantizes the
same codes and the pool quantizes per token slot.

A tensor-parallel arm replays the same workloads through the mesh-sharded
paged batcher (TP=2 in tier-1; TP=4 on a widened-KV smoke variant in the
slow tier) across host/device sync x prefix-cache on/off: the column-
parallel layout (serving/layout.py) never reassociates a reduction, so
greedy streams must stay BIT-identical to the sequential reference and the
sharded pool must drain like the single-device pool.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.scheduler import ContinuousBatcher, PagedBatcher, Request
from repro.serving.spec import SpecConfig

LEN_PALETTE = (4, 9, 20, 32, 33, 48, 57, 64)
BS = 16

# smoke_model: session-scoped fixture from conftest.py


def _reference(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=160, dtype=jnp.float32)
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _workload(cfg, seed, n=5):
    rng = np.random.default_rng(seed)
    lens = rng.choice(LEN_PALETTE, size=n)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in lens]
    budgets = [int(b) for b in rng.integers(1, 8, size=n)]
    order = list(rng.permutation(n))
    return prompts, budgets, order


def _arms(cfg, params, n, max_len, **extra):
    """The six scheduler arms; ``extra`` kwargs (e.g. ``tracer=``) are
    forwarded to every constructor."""
    nb = 1 + n * -(-max_len // BS)
    paged = dict(num_blocks=nb, block_size=BS,
                 max_blocks_per_seq=-(-max_len // BS), decode_width=3,
                 buckets=(32, 64), cache_dtype=jnp.float32, **extra)
    return {
        "dense": lambda: ContinuousBatcher(cfg, params, max_batch=3,
                                           max_len=max_len,
                                           buckets=(32, 64), **extra),
        "paged_host": lambda: PagedBatcher(cfg, params, sync="host",
                                           **paged),
        "paged_device": lambda: PagedBatcher(cfg, params, sync="device",
                                             window=3, **paged),
        "mixed": lambda: PagedBatcher(cfg, params, sync="device",
                                      window=3, mixed_batch=True, **paged),
        # spec arms: token identity is draft-agnostic — the independent
        # random-init draft mostly REJECTS (rollback storm), the self-draft
        # mostly accepts (K+1 tokens per verify dispatch)
        "spec_indep": lambda: PagedBatcher(
            cfg, params, sync="host",
            spec=SpecConfig(k=3, draft=get_smoke_config("smollm-135m").with_(
                param_dtype="float32", compute_dtype="float32")), **paged),
        "spec_self_device": lambda: PagedBatcher(cfg, params, sync="device",
                                                 spec=SpecConfig(k=2),
                                                 **paged),
    }


def _shared_prefix_workload(cfg, seed, n=6):
    """Random shared/unshared prompt mix for the prefix-cache arm: two
    'system prompts' (block-aligned and not), each request independently
    picks one of them or none, then appends a random tail — so hits of
    every depth, full-prompt CoW admissions (empty tails), and cold misses
    all interleave under random arrival order."""
    rng = np.random.default_rng(1000 + seed)
    systems = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (3 * BS, 2 * BS + 5)]
    prompts = []
    for _ in range(n):
        head = systems[int(rng.integers(3)) % 2] if rng.random() < 0.75 \
            else np.zeros((0,), np.int32)
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.choice((0, 3, 9, BS, 33)))
                            ).astype(np.int32)
        prompt = np.concatenate([head, tail])
        if len(prompt) == 0:
            prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        prompts.append(prompt)
    budgets = [int(b) for b in rng.integers(1, 8, size=n)]
    order = list(rng.permutation(n))
    return prompts, budgets, order


@pytest.mark.tier1
@pytest.mark.parametrize("seed", [0, pytest.param(1, marks=pytest.mark.slow),
                                  pytest.param(2, marks=pytest.mark.slow)])
def test_prefix_cache_arms_token_identical_and_leak_free(smoke_model, seed):
    """Prefix-cache fuzz arm: under random shared/unshared prompt mixes —
    submitted twice, so the second pass hits blocks retired by the first —
    the host- and device-sync prefix-cache arms stay token-identical to
    the sequential reference and the pool drains (cache retention is not
    a leak)."""
    cfg, model, params = smoke_model
    prompts, budgets, order = _shared_prefix_workload(cfg, seed)
    max_len = 3 * BS + 33 + 8 + 1
    nb = 1 + 2 * len(prompts) * -(-max_len // BS)
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]
    for sync, kw in (("host", {}), ("device", {"window": 3})):
        batcher = PagedBatcher(cfg, params, sync=sync, num_blocks=nb,
                               block_size=BS, prefix_cache=True,
                               max_blocks_per_seq=-(-max_len // BS),
                               decode_width=3, buckets=(32, 64),
                               cache_dtype=jnp.float32, **kw)
        for wave in range(2):                # wave 2 replays: warm hits
            reqs = [Request(rid=i, prompt=prompts[i],
                            max_new_tokens=budgets[i]) for i in order]
            batcher.run(reqs)
            for r in reqs:
                assert r.done, (sync, wave, seed, r.rid)
                assert r.output == refs[r.rid], (sync, wave, seed, r.rid)
        batcher.kv.assert_drained()
        st = batcher.stats()
        assert st["prefix_hits"] > 0, (sync, seed)
        assert st["prefix_tokens_reused"] > 0, (sync, seed)


@pytest.mark.tier1
@pytest.mark.parametrize("seed", [0, pytest.param(1, marks=pytest.mark.slow),
                                  pytest.param(2, marks=pytest.mark.slow)])
def test_all_arms_token_identical_and_leak_free(smoke_model, seed):
    cfg, model, params = smoke_model
    prompts, budgets, order = _workload(cfg, seed)
    max_len = max(LEN_PALETTE) + 8 + 1
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]

    for name, make in _arms(cfg, params, len(prompts), max_len).items():
        batcher = make()
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
                for i in order]                  # randomized arrival order
        batcher.run(reqs)
        for r in reqs:
            assert r.done, (name, seed, r.rid)
            assert r.output == refs[r.rid], (name, seed, r.rid)
        if isinstance(batcher, PagedBatcher):
            batcher.kv.assert_drained()          # zero leaked blocks
            assert not batcher.busy
            if batcher.spec is not None:
                st = batcher.stats()
                assert st["verify_dispatches"] == st["decode_dispatches"] > 0
                assert 0.0 <= st["acceptance_rate"] <= 1.0
                assert st["decode_steps"] >= st["spec_rounds"]
        assert not batcher.queue


# ------------------------------------------------- trace cross-check arm --

@pytest.mark.tier1
def test_trace_counters_reconcile_on_every_arm(smoke_model):
    """Observability cross-check: every arm replayed with a Tracer attached
    must (a) stay token-identical (tracing is observation only), (b) emit
    trace B-events whose per-kind counts equal the stats() dispatch
    counters, and (c) reconcile the tracer's mirrored counters against
    stats() exactly (counter_reconciliation == {})."""
    from repro.serving.telemetry import FakeClock
    from repro.serving.trace import Tracer, counter_reconciliation
    cfg, model, params = smoke_model
    prompts, budgets, order = _workload(cfg, seed=0)
    max_len = max(LEN_PALETTE) + 8 + 1
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]

    for name in _arms(cfg, params, len(prompts), max_len):
        tracer = Tracer(FakeClock(),
                        cost_model=lambda kind, pred: max(pred, 10.0) * 1e-6)
        batcher = _arms(cfg, params, len(prompts), max_len,
                        tracer=tracer)[name]()
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
                for i in order]
        batcher.run(reqs)
        for r in reqs:
            assert r.output == refs[r.rid], (name, r.rid)

        assert counter_reconciliation(tracer, batcher.stats()) == {}, name
        by_kind = {}
        for e in tracer.events:
            if e["ph"] == "B" and e.get("cat") == "dispatch":
                by_kind[e["name"]] = by_kind.get(e["name"], 0) + 1
        st = batcher.stats()
        assert by_kind.get("prefill_chunk", 0) == st["prefill_dispatches"], \
            (name, by_kind)
        decode_kinds = ("decode_step", "decode_window", "mixed_step",
                        "mixed_window", "paged_verify")
        assert sum(by_kind.get(k, 0) for k in decode_kinds) \
            == st["decode_dispatches"], (name, by_kind)
        assert sum(by_kind.get(k, 0) for k in ("mixed_step", "mixed_window")) \
            == st["fused_steps"], (name, by_kind)
        if st.get("verify_dispatches"):
            assert by_kind["paged_verify"] == st["verify_dispatches"], name
        assert tracer.dropped == 0 and tracer.n_events > 0


# ------------------------------------------------- tensor-parallel arm ----

def _tp_fuzz(cfg, model, params, tp, sync, prefix, seed):
    """One fuzz workload through the TP paged batcher: greedy streams must
    be BIT-IDENTICAL to the sequential single-device reference (the layout
    only all-gathers output-column slices — no reduction is reassociated)
    and the sharded pool must drain exactly like the single-device pool."""
    from repro.launch.mesh import make_host_mesh
    prompts, budgets, order = _workload(cfg, seed)
    max_len = max(LEN_PALETTE) + 8 + 1
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]
    nb = 1 + len(prompts) * -(-max_len // BS)
    kw = dict(num_blocks=nb, block_size=BS,
              max_blocks_per_seq=-(-max_len // BS), decode_width=3,
              buckets=(32, 64), cache_dtype=jnp.float32,
              mesh=make_host_mesh(1, tp), sync=sync, prefix_cache=prefix)
    if sync == "device":
        kw["window"] = 3
    batcher = PagedBatcher(cfg, params, **kw)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
            for i in order]
    batcher.run(reqs)
    for r in reqs:
        assert r.done, (tp, sync, prefix, seed, r.rid)
        assert r.output == refs[r.rid], (tp, sync, prefix, seed, r.rid)
    batcher.kv.assert_drained()
    assert not batcher.busy and not batcher.queue
    assert batcher.stats()["tp"] == tp


@pytest.mark.tier1
@pytest.mark.parametrize("seed", [0, pytest.param(1, marks=pytest.mark.slow)])
@pytest.mark.parametrize("sync,prefix", [("host", False), ("device", False),
                                         ("host", True), ("device", True)])
def test_tp2_fuzz_token_identical_and_leak_free(smoke_model, sync, prefix,
                                                seed):
    cfg, model, params = smoke_model
    _tp_fuzz(cfg, model, params, 2, sync, prefix, seed)


@pytest.fixture(scope="module")
def tp4_smoke_model():
    """TP=4 needs n_kv_heads % 4 == 0: the widened-KV smoke variant."""
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32",
                                              n_kv_heads=4)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(7))


@pytest.mark.slow
@pytest.mark.parametrize("sync,prefix", [("host", False), ("device", False),
                                         ("host", True), ("device", True)])
def test_tp4_fuzz_token_identical_and_leak_free(tp4_smoke_model, sync,
                                                prefix):
    cfg, model, params = tp4_smoke_model
    _tp_fuzz(cfg, model, params, 4, sync, prefix, seed=0)


# ----------------------------------------------------- quantized serving --

def _paged_reference(model, params, prompt, n, kv_quant=None, max_len=160):
    """Sequential single-request reference through the PAGED path: the
    oracle for kv-quant arms, where pool numerics (quantize-on-scatter is
    per token slot, so chunking- and batch-width-invariant) replace the
    dense cache's."""
    nbs = -(-max_len // BS)
    pool = model.init_paged_cache(num_blocks=nbs + 1, block_size=BS,
                                  dtype=jnp.float32, kv_quant=kv_quant)
    bt = jnp.arange(1, nbs + 1, dtype=jnp.int32)[None]
    logits, pool = model.paged_prefill(params, jnp.asarray(prompt)[None],
                                       pool, block_table=bt, start_index=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    length = len(prompt)
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, pool = model.paged_decode_step(
            params, tok, pool, block_tables=bt,
            lengths=jnp.asarray([length]))
        out.append(int(jnp.argmax(logits[0, -1])))
        length += 1
    return out


# (weight_quant, kv_quant) per fuzz arm family
QUANT_ARMS = {
    "w_int8": ("int8", None),
    "w_w4a16": ("w4a16", None),
    "kv_int8": (None, "int8"),
    "w_w4a16_kv_int8": ("w4a16", "int8"),
}


@pytest.mark.tier1
@pytest.mark.parametrize("seed", [0, pytest.param(1, marks=pytest.mark.slow),
                                  pytest.param(2, marks=pytest.mark.slow)])
@pytest.mark.parametrize("quant", sorted(QUANT_ARMS))
def test_quant_arms_token_identical_and_leak_free(smoke_model, seed, quant):
    """Quantized serving fuzz: weight-quant, kv-quant, and both, through the
    dense / paged-host / paged-device / mixed arms. Every arm quantizes the
    SAME weights to the same codes and the int8 pool quantizes per token
    slot, so greedy streams must be token-identical to a sequential
    QUANTIZED reference (dense for weight-only, paged for kv-quant), and
    the pool must drain."""
    from repro.models.quant import dequantize_params, quantize_params
    cfg, model, params = smoke_model
    wq, kq = QUANT_ARMS[quant]
    prompts, budgets, order = _workload(cfg, seed)
    max_len = max(LEN_PALETTE) + 8 + 1
    # references run on the DEQUANTIZED expansion of the same codes: f32
    # dequant-then-matmul is bitwise what matmul_any executes, so this is
    # the same oracle while reusing the suite's fp-compiled graphs (and it
    # additionally pins quantized execution == dequantize-then-fp).
    rparams = (dequantize_params(quantize_params(params, cfg, wq))
               if wq else params)
    refs = [(_paged_reference(model, rparams, p, m, kv_quant=kq)
             if kq else _reference(model, rparams, p, m))
            for p, m in zip(prompts, budgets)]

    nb = 1 + len(prompts) * -(-max_len // BS)
    paged = dict(num_blocks=nb, block_size=BS,
                 max_blocks_per_seq=-(-max_len // BS), decode_width=3,
                 buckets=(32, 64), cache_dtype=jnp.float32,
                 weight_quant=wq, kv_quant=kq)
    arms = {
        "paged_host": lambda: PagedBatcher(cfg, params, sync="host", **paged),
        "paged_device": lambda: PagedBatcher(cfg, params, sync="device",
                                             window=3, **paged),
        "mixed": lambda: PagedBatcher(cfg, params, sync="device", window=3,
                                      mixed_batch=True, **paged),
    }
    if kq is None:      # the dense batcher has no paged pool to quantize
        arms["dense"] = lambda: ContinuousBatcher(
            cfg, params, max_batch=3, max_len=max_len, buckets=(32, 64),
            weight_quant=wq)
    for name, make in arms.items():
        batcher = make()
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
                for i in order]
        batcher.run(reqs)
        for r in reqs:
            assert r.done, (quant, name, seed, r.rid)
            assert r.output == refs[r.rid], (quant, name, seed, r.rid)
        if isinstance(batcher, PagedBatcher):
            batcher.kv.assert_drained()
        assert not batcher.busy and not batcher.queue


# ----------------------------------------------------------- open loop ----

@pytest.mark.tier1
@pytest.mark.parametrize("seed", [0, pytest.param(1, marks=pytest.mark.slow),
                                  pytest.param(2, marks=pytest.mark.slow)])
@pytest.mark.parametrize("kind", ["poisson", "burst"])
def test_open_loop_arms_token_identical_and_leak_free(smoke_model, seed,
                                                      kind):
    """Open-loop fuzz arm: the same randomized workloads, but arriving on a
    seeded Poisson / bursty schedule through the async ingress (FakeClock,
    virtual per-tick cost — zero real sleeps). Queueing, deferral and
    multi-tick admission must be invisible to the OUTPUT: every stream is
    token-identical to the sequential reference, every terminal event fires
    exactly once, and the pool drains."""
    from repro.serving.ingress import (AsyncServer, arrival_times,
                                       open_loop_workload)
    from repro.serving.telemetry import FakeClock
    cfg, model, params = smoke_model
    prompts, budgets, order = _workload(cfg, seed)
    max_len = max(LEN_PALETTE) + 8 + 1
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]
    times = arrival_times(kind, 200.0, len(prompts), seed)
    arms = _arms(cfg, params, len(prompts), max_len)
    for name in ("dense", "paged_host", "paged_device", "mixed"):
        batcher = arms[name]()
        server = AsyncServer(batcher, clock=FakeClock(), step_time_s=1e-3)
        handles = server.run_sync(open_loop_workload(
            [prompts[i] for i in order], [budgets[i] for i in order], times))
        for j, h in enumerate(handles):       # handle j carries rid order[j]
            assert h.done and h.terminal_events == 1, (name, kind, seed, j)
            assert h.tokens == refs[order[j]], (name, kind, seed, j)
        if isinstance(batcher, PagedBatcher):
            batcher.kv.assert_drained()
        assert not batcher.busy and not batcher.queue
        rep = server.report()
        assert rep["n_finished"] == len(prompts)
        assert all(t.queue_delay >= 0
                   for t in server.telemetry.traces.values())


@pytest.mark.tier1
@pytest.mark.parametrize("seed", [3, pytest.param(4, marks=pytest.mark.slow),
                                  pytest.param(5, marks=pytest.mark.slow)])
def test_random_preemption_points_token_identical(smoke_model, seed):
    """Preempt→resume property fuzz: at RANDOM steps, evict a random live
    lane mid-decode and resubmit it as prompt+emitted with the remaining
    budget. However the preemptions interleave, the stitched streams must
    be bit-identical to the never-preempted sequential reference and the
    pool must drain (retired-through-cache blocks included). Terminates
    because every attempt emits at least its prefill token."""
    cfg, model, params = smoke_model
    prompts, budgets, order = _workload(cfg, seed)
    max_len = max(LEN_PALETTE) + 8 + 1
    nb = 1 + len(prompts) * -(-max_len // BS)
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, budgets)]
    batcher = PagedBatcher(cfg, params, sync="host", num_blocks=nb,
                           block_size=BS, prefix_cache=True,
                           max_blocks_per_seq=-(-max_len // BS),
                           decode_width=3, buckets=(32, 64),
                           cache_dtype=jnp.float32)
    rng = np.random.default_rng(100 + seed)
    reqs = {i: Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
            for i in order}
    for i in order:
        batcher.submit(reqs[i])
    prefix = {i: [] for i in order}          # tokens from prior attempts
    steps = 0
    while batcher.busy:
        batcher.step()
        steps += 1
        assert steps < 500, "preemption fuzz failed to converge"
        if rng.random() < 0.35:
            cands = [li for li, ln in enumerate(batcher.lanes)
                     if ln is not None and ln.budget > 0]
            if cands:
                victim = batcher.preempt(int(rng.choice(cands)))
                prefix[victim.rid].extend(int(t) for t in victim.output)
                rem = budgets[victim.rid] - len(prefix[victim.rid])
                assert rem >= 1, "preempted a finishing lane"
                resumed = Request(
                    rid=victim.rid,
                    prompt=np.concatenate([
                        prompts[victim.rid],
                        np.asarray(prefix[victim.rid], np.int32)]),
                    max_new_tokens=rem)
                reqs[victim.rid] = resumed
                batcher.submit(resumed)
    for i in order:
        assert reqs[i].done, (seed, i)
        assert prefix[i] + reqs[i].output == refs[i], (seed, i)
    batcher.kv.assert_drained()
    assert batcher.preemptions > 0, "fuzz never exercised a preemption"


@pytest.mark.tier1
def test_preempt_resume_reuses_prefix_cache(smoke_model):
    """Recompute-on-resume rides the prefix cache: preempting a request
    whose KV spans full blocks and resuming it must allocate strictly
    FEWER fresh blocks with the cache on (retired blocks hash-match and
    reattach) than cold — and produce the identical stream either way."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(77)
    prompt = rng.integers(0, cfg.vocab_size, 3 * BS).astype(np.int32)
    n = 6
    ref = _reference(model, params, prompt, n)
    allocs = {}
    for cached in (False, True):
        batcher = PagedBatcher(cfg, params, sync="host", num_blocks=17,
                               block_size=BS, max_blocks_per_seq=5,
                               decode_width=2, buckets=(32, 64),
                               cache_dtype=jnp.float32, prefix_cache=cached)
        req = Request(rid=0, prompt=prompt, max_new_tokens=n)
        batcher.submit(req)
        batcher.step()
        batcher.step()                       # a few tokens in, mid-decode
        victim = batcher.preempt(0)
        emitted = [int(t) for t in victim.output]
        assert 1 <= len(emitted) < n
        resumed = Request(rid=0, prompt=np.concatenate([
            prompt, np.asarray(emitted, np.int32)]),
            max_new_tokens=n - len(emitted))
        batcher.submit(resumed)
        while batcher.busy:
            batcher.step()
        assert emitted + resumed.output == ref, cached
        batcher.kv.assert_drained()
        allocs[cached] = batcher.kv.allocator.total_allocs
        if cached:
            assert batcher.stats()["prefix_hits"] > 0
    assert allocs[True] < allocs[False], allocs
