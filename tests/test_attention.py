"""Blockwise attention + chunked recurrences vs oracles (seeded sweeps).

Formerly hypothesis property tests; rewritten as seeded ``numpy.random``
parameterizations so the suite collects on a clean environment with no
third-party test deps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import blockwise_attention, dense_attention
from repro.models.mamba2 import ssd_chunked
from repro.models.rwkv6 import wkv6_chunked, wkv6_recurrent

RNG = jax.random.PRNGKey(1)


def _sampled_cases(seed, n, sampler):
    rng = np.random.default_rng(seed)
    return [sampler(rng) for _ in range(n)]


# decode-style and prefill-style shapes, ragged vs aligned block boundaries
BLOCKWISE_CASES = [
    # (sq, sk, g, block, causal)
    (1, 64, 4, 16, True),        # decode step, GQA
    (1, 8, 1, 8, True),          # single block exactly
    (40, 40, 2, 16, True),       # prefill, ragged tail (40 % 16 != 0)
    (17, 33, 1, 32, True),       # both ragged
    (8, 64, 4, 8, False),        # bidirectional (encoder)
    (40, 64, 2, 32, False),
] + _sampled_cases(7, 4, lambda r: (int(r.integers(1, 41)),
                                    int(r.integers(8, 65)),
                                    int(r.choice([1, 2, 4])),
                                    int(r.choice([8, 16, 32])),
                                    bool(r.integers(2))))


@pytest.mark.parametrize("sq,sk,g,block,causal", BLOCKWISE_CASES)
def test_blockwise_matches_dense(sq, sk, g, block, causal):
    Hkv, D = 2, 16
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, sq, Hkv * g, D))
    k = jax.random.normal(ks[1], (2, sk, Hkv, D))
    v = jax.random.normal(ks[2], (2, sk, Hkv, D))
    # decode-style positions: queries at the end of the kv window
    q_pos = jnp.arange(sk - sq, sk) if sq <= sk else jnp.arange(sq)
    kv_pos = jnp.arange(sk)
    o1 = blockwise_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                             causal=causal, block_k=block)
    o2 = dense_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


WKV_CASES = [(4, 8), (37, 16), (100, 32), (64, 16), (31, 8), (16, 16)]


@pytest.mark.parametrize("S,chunk", WKV_CASES)
def test_wkv6_chunked_matches_recurrent(S, chunk):
    B, H, hd = 2, 2, 8
    ks = jax.random.split(RNG, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    y1, s1 = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    y2, s2 = wkv6_recurrent(r, k, v, lw, u)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


SSD_CASES = [(4, 8), (29, 16), (80, 32), (48, 16), (33, 8)]


@pytest.mark.parametrize("S,chunk", SSD_CASES)
def test_ssd_chunked_matches_recurrence(S, chunk):
    B, nh, hd, N = 2, 3, 8, 8
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y1, st1 = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk)

    Sst = jnp.zeros((B, nh, hd, N))
    ys = []
    for t in range(S):
        da = jnp.exp(dt[:, t] * A[None, :])
        Sst = da[:, :, None, None] * Sst + jnp.einsum(
            "bhp,bn,bh->bhpn", xh[:, t], B_[:, t], dt[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", C_[:, t], Sst))
    y2 = jnp.stack(ys, 1)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(st1 - Sst))) < 1e-4


def test_wkv6_state_passing_across_calls():
    """Chunked calls with carried state == one long call (serving contract)."""
    B, S, H, hd = 1, 64, 2, 8
    ks = jax.random.split(RNG, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    y_full, s_full = wkv6_chunked(r, k, v, lw, u, chunk=16)
    ya, sa = wkv6_chunked(r[:, :40], k[:, :40], v[:, :40], lw[:, :40], u, chunk=16)
    yb, sb = wkv6_chunked(r[:, 40:], k[:, 40:], v[:, 40:], lw[:, 40:], u,
                          chunk=16, state=sa)
    assert float(jnp.max(jnp.abs(jnp.concatenate([ya, yb], 1) - y_full))) < 1e-4
    assert float(jnp.max(jnp.abs(sb - s_full))) < 1e-4
