import os
from dataclasses import dataclass

# Tests run single-device unless a test makes its own host mesh via XLA flags
# in a subprocess. Do NOT set xla_force_host_platform_device_count here (the
# dry-run owns that); 8 host devices are enabled for the distributed tests
# only, which is safe for everything else.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_compiled_cache_per_module():
    """Drop jit/pjit executable caches at module boundaries. Every compiled
    XLA:CPU executable pins mmapped code pages for the life of the process;
    a full single-process tier-1 run accumulates enough of them to exhaust
    the kernel's vm.max_map_count ceiling (65530 on stock Linux), at which
    point the NEXT backend_compile mmap fails and jaxlib segfaults. Clearing
    per module caps live executables at one module's worth (~a third of the
    ceiling) at the cost of cross-module recompiles of the shared
    smoke_model graphs."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def smoke_model():
    """The float32 llama3 smoke model the serving tests share: (cfg, model,
    params). Session-scoped — params are never donated by any consumer, so
    one init serves every module."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


# ---------------------------------------------------------------------------
# Shared kernel-conformance parameterization (tests/test_kernel_conformance.py)
#
# ONE case grid drives every Pallas kernel package: each package maps the
# canonical (M, K, N) triple onto its own operand shapes, applies the SAME
# pad-to-128 policy production uses (core/partition.py::HeteroCtx._mxu /
# kernels/*/ops.py head-dim padding), and compares against its ref.py oracle.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelCase:
    """Canonical conformance case: M is the token/row dim (ragged allowed),
    K the contraction dim (odd/misaligned allowed), N the output dim."""
    name: str
    M: int
    K: int
    N: int


CONFORMANCE_CASES = (
    KernelCase("aligned", 128, 128, 128),        # every dim on a 128 tile
    KernelCase("rect", 256, 384, 128),           # multi-tile, K-major
    KernelCase("ragged_m", 77, 128, 128),        # ragged token count
    KernelCase("odd_k", 128, 97, 128),           # genuinely odd K
    KernelCase("ragged_both", 53, 96, 256),      # ragged M and misaligned K
    KernelCase("quant_edges", 64, 95, 192),      # ragged M, odd K, ragged N —
    #                                   the shape family quantized serving
    #                                   routes through per-channel scales
)

# quantized serving entry points the conformance tier must cover
# (test_kernel_conformance.py holds each against a dequantize-then-fp
# reference; the meta-test pins this list so the grid can only grow)
QUANT_SERVING_CHECKS = ("paged_prefill", "paged_decode_step", "mixed_step",
                        "paged_verify", "int8_pool_gather")

# activation dtypes the serving/engine paths actually run; per-kernel
# tolerance reflects the output-dtype rounding of the kernel contract
CONFORMANCE_DTYPES = ("float32", "bfloat16", "float16")
DTYPE_TOL = {"float32": 2e-6, "bfloat16": 2e-2, "float16": 4e-3}


def rel_err(a, b) -> float:
    """Max elementwise error of ``a`` vs oracle ``b``, relative to |b|max —
    the single conformance metric every kernel package is held to."""
    a32 = jnp.asarray(a).astype(jnp.float32)
    b32 = jnp.asarray(b).astype(jnp.float32)
    return float(jnp.max(jnp.abs(a32 - b32))
                 / (jnp.max(jnp.abs(b32)) + 1e-9))


def pad_to(x, mult: int, axis: int):
    """Zero-pad ``axis`` up to a multiple of ``mult`` (the production
    stage-padding policy for the aligned MXU path)."""
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - r)
    return jnp.pad(x, pads)
