import os

# Tests run single-device unless a test makes its own host mesh via XLA flags
# in a subprocess. Do NOT set xla_force_host_platform_device_count here (the
# dry-run owns that); 8 host devices are enabled for the distributed tests
# only, which is safe for everything else.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
