"""PartitionSolver property tests (paper §4.2/§4.4 invariants).

Seeded sweeps over (arch, site, M):
  * the chosen strategy is never worse than running everything on the
    flexible path (`xla_only` is always a candidate, so `solve_site` is a
    min over a set containing it);
  * every weight/hybrid split point is 128-aligned and strictly inside
    (0, N) — the MXU path physically cannot run a misaligned column block;
  * MIXED (stage-parallel serving pair) decisions beat serializing the two
    stages whenever the solver reports a gain, and plans round-trip
    through save/load to EQUAL decisions, mixed included.
"""
import pytest

from repro.configs import get_config
from repro.core.profiler import profile_analytic
from repro.core.solver import ALIGN, PartitionPlan, PartitionSolver

ARCHS = ("llama3-8b", "qwen2-moe-a2.7b", "tinyllama-1.1b")
MS = (1, 7, 64, 100, 128, 192, 300, 511, 512, 1000, 2048)


@pytest.fixture(scope="module", params=ARCHS)
def solver(request):
    cfg = get_config(request.param)
    return cfg, PartitionSolver(profile_analytic(cfg), sync_mode="fast")


@pytest.mark.tier1
def test_best_never_worse_than_xla_only(solver):
    cfg, s = solver
    for site in s.table.sites:
        for M in MS:
            dec = s.solve_site(site, M)
            t_xla = s.table.lookup(site, M, "xla")
            assert dec.t_us <= t_xla + 1e-9, \
                f"{cfg.name}/{site}/M={M}: {dec.describe()} vs xla {t_xla}"


@pytest.mark.tier1
def test_split_points_aligned_and_interior(solver):
    cfg, s = solver
    for site in s.table.sites:
        _, N = s.table.sites[site]
        for M in MS:
            dec = s.solve_site(site, M)
            if dec.strategy in ("weight", "hybrid"):
                assert dec.n_split % ALIGN == 0, dec.describe()
                assert 0 < dec.n_split < N, dec.describe()
            if dec.strategy in ("act", "hybrid"):
                assert 0 < dec.m_bucket < M, dec.describe()


@pytest.mark.tier1
def test_mixed_pair_consistency(solver):
    """MIXED decisions: strategy tag, prefill bucket recorded, and the
    fused latency never exceeds serializing the two single-stream stages
    (combine_dual over a superset of each stream's bandwidth)."""
    cfg, s = solver
    for site in list(s.table.sites)[:4]:
        for (mp, md) in ((64, 4), (128, 8), (256, 8)):
            dec = s.solve_mixed(site, mp, md)
            assert dec.strategy == "mixed" and dec.m_bucket == mp
            assert dec.M == mp + md
            assert s.mixed_gain_us(site, mp, md) >= 0.0, dec.describe()


@pytest.mark.tier1
def test_plan_roundtrip_equal_decisions(solver, tmp_path):
    """save/load -> EQUAL Decision dataclasses for every (site, M) and
    every mixed (site, m_prefill, m_decode) key, plus kv_mode/sync_mode."""
    cfg, s = solver
    plan = s.solve(cfg, Ms=(1, 100, 256), mixed_pairs=((64, 4), (256, 8)))
    assert plan.mixed_decisions, "mixed_pairs produced no MIXED decisions"
    p = tmp_path / "plan.json"
    plan.save(p)
    plan2 = PartitionPlan.load(p)
    assert plan2.arch == plan.arch and plan2.sync_mode == plan.sync_mode
    assert plan2.kv_mode == plan.kv_mode
    assert plan2.decisions == plan.decisions
    assert plan2.mixed_decisions == plan.mixed_decisions
    for key, dec in plan2.mixed_decisions.items():
        site, mp, md = key
        assert dec.strategy == "mixed"
        assert plan2.mixed_decision(site, mp, md) == dec
