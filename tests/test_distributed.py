"""Distribution tests on an 8-host-device mesh: sharding rules, small-mesh
compiles, pipeline parallelism, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_smoke_config
from repro.distributed.compression import (compress_grads_with_feedback,
                                           compressed_psum, init_error)
from repro.distributed.sharding import (batch_sharding, cache_specs,
                                        param_specs, sanitize_spec)
from repro.distributed.compat import set_mesh, shard_map
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_step_and_specs
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


def small_mesh():
    return make_host_mesh(2, 4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_all_leaves(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(RNG))
    mesh = small_mesh()
    specs = param_specs(shapes, mesh)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    # every spec valid for its leaf (divisibility sanitized)
    for s, sp in zip(flat_shapes, flat_specs):
        for dim, ax in zip(s.shape, list(sp)):
            if ax is not None:
                n = np.prod([mesh.shape[a] for a in
                             ((ax,) if isinstance(ax, str) else ax)])
                assert dim % n == 0


def test_sanitize_spec():
    mesh = small_mesh()        # model axis = 4
    assert sanitize_spec(P("model"), (503,), mesh) == P()       # 503 % 4 != 0
    assert sanitize_spec(P("model"), (512,), mesh) == P("model")
    assert sanitize_spec(P(("data",), "model"), (1, 8), mesh) == P(None, "model")


def _compile_cells():
    """Supported (arch, shape) cells only — the support gate is static
    config knowledge (e.g. encoder-only hubert has no decode shapes), so
    unsupported combinations are excluded at collection instead of
    producing perpetual runtime skips. ``test_cell_support_gate`` pins the
    gate itself."""
    from repro.configs.base import cell_is_supported
    cells = []
    for arch in ("llama3-8b", "qwen2-moe-a2.7b", "zamba2-2.7b", "rwkv6-3b",
                 "hubert-xlarge"):
        for shape_name in ("train_4k", "decode_32k"):
            cfg = get_smoke_config(arch)
            shape = dataclasses.replace(SHAPES[shape_name], seq_len=64,
                                        global_batch=4)
            if cell_is_supported(cfg, shape)[0]:
                cells.append((arch, shape_name))
    return cells


def test_cell_support_gate():
    """The only gated-out compile cell is encoder-only hubert x decode
    (no autoregressive path to compile) — if the gate widens, the compile
    grid above must be revisited, so pin it."""
    from repro.configs.base import cell_is_supported
    cells = _compile_cells()
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert len(cells) == 9
    ok, reason = cell_is_supported(
        get_smoke_config("hubert-xlarge"),
        dataclasses.replace(SHAPES["decode_32k"], seq_len=64, global_batch=4))
    assert not ok and reason


@pytest.mark.parametrize("arch,shape_name", _compile_cells())
def test_small_mesh_compile(arch, shape_name):
    """The dry-run pipeline end-to-end on a 2x4 host mesh, reduced shapes."""
    from repro.distributed.sharding import activation_sharding
    cfg = get_smoke_config(arch)
    shape = dataclasses.replace(SHAPES[shape_name], seq_len=64, global_batch=4)
    mesh = small_mesh()
    with set_mesh(mesh):
        jf, args, act_spec = make_step_and_specs(cfg, mesh, shape)
        with activation_sharding(act_spec):
            compiled = jf.lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_pipeline_parallel_matches_serial():
    from repro.distributed.pipeline import make_pipeline_forward
    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    ks = jax.random.split(RNG, 2)
    Ws = jax.random.normal(ks[0], (n_stages, 1, d, d)) / np.sqrt(d)
    x = jax.random.normal(ks[1], (n_micro, mb, d))

    def layer_fn(w, h):
        return jnp.tanh(h @ w[0])

    pipe = make_pipeline_forward(layer_fn, n_stages, n_micro, mesh)
    with set_mesh(mesh):
        y = pipe(Ws, x)
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ Ws[s, 0])
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-5


def test_compression_error_feedback_unbiased():
    """EF carries the residual: sum of compressed grads -> sum of true grads."""
    g = jax.random.normal(RNG, (256,)) * 0.01
    err = jnp.zeros((256,))
    acc_c = jnp.zeros((256,))
    for i in range(50):
        comp, err = compress_grads_with_feedback({"g": g}, {"g": err["g"] if
                                                 isinstance(err, dict) else err})
        err = err["g"]
        acc_c = acc_c + comp["g"]
    acc_true = 50 * g
    rel = float(jnp.linalg.norm(acc_c - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01        # residual bounded, not accumulating


def test_compressed_psum_close_to_exact():
    mesh = jax.make_mesh((8,), ("d",))
    x = jax.random.normal(RNG, (8, 128))

    @jax.jit
    def f(x):
        return shard_map(lambda xs: compressed_psum(xs, "d"),
                             mesh=mesh, in_specs=P("d"),
                             out_specs=P("d"))(x)
    with set_mesh(mesh):
        y = f(x)
    exact = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
    rel = float(jnp.max(jnp.abs(y - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.05        # int8 quantized reduction


def test_split_kv_decode_matches_oracle():
    """Mesh split-KV flash-decoding == single-device decode oracle."""
    from repro.distributed.split_kv import split_kv_decode_update_attend
    from repro.kernels.decode_attention.ref import decode_attention_ref
    mesh = small_mesh()
    B, Smax, Hq, Hkv, D = 4, 64, 8, 2, 16
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
    kn = jax.random.normal(ks[1], (B, 1, Hkv, D), jnp.float32)
    vn = jax.random.normal(ks[2], (B, 1, Hkv, D), jnp.float32)
    kc = jax.random.normal(ks[3], (B, Smax, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[4], (B, Smax, Hkv, D), jnp.float32)
    jf = jax.jit(split_kv_decode_update_attend)   # hoisted: one trace cache
    for pos in (0, 15, 16, 37, 63):      # includes shard boundaries
        idx = jnp.asarray(pos, jnp.int32)
        with set_mesh(mesh):
            out, ck, cv = jf(q, kn, vn, kc, vc, idx)
        kc2 = kc.at[:, pos].set(kn[:, 0])
        vc2 = vc.at[:, pos].set(vn[:, 0])
        ref = decode_attention_ref(q[:, 0], kc2, vc2, pos + 1)
        assert float(jnp.abs(out[:, 0] - ref).max()) < 1e-5, pos
        assert float(jnp.abs(np.asarray(ck) - np.asarray(kc2)).max()) == 0.0


def test_split_kv_indivisible_smax_raises():
    """Regression: Smax not divisible by the model-axis size used to
    silently floor-divide — the trailing ``Smax % n_shards`` slots were
    never attended over and writes to them were dropped. Must raise."""
    from repro.distributed.split_kv import split_kv_decode_update_attend
    mesh = small_mesh()                       # model axis = 4
    B, Smax, Hq, Hkv, D = 4, 66, 8, 2, 16    # 66 % 4 == 2
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
    kn = jax.random.normal(ks[1], (B, 1, Hkv, D), jnp.float32)
    vn = jax.random.normal(ks[2], (B, 1, Hkv, D), jnp.float32)
    kc = jax.random.normal(ks[3], (B, Smax, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[4], (B, Smax, Hkv, D), jnp.float32)
    with set_mesh(mesh):
        with pytest.raises(ValueError, match="not divisible"):
            split_kv_decode_update_attend(q, kn, vn, kc, vc,
                                          jnp.asarray(65, jnp.int32))


def test_combine_split_softmax_matches_dense_on_ragged_lengths():
    """The split-softmax combine == one dense softmax-weighted sum, on
    RAGGED lengths: per-batch valid prefixes that straddle shard
    boundaries, including a length-1 row whose non-owner shards see
    all-NEG_INF scores (their partials must contribute exactly zero)."""
    from repro.distributed.split_kv import NEG_INF, combine_split_softmax
    B, K, Hkv, G, D = 3, 48, 2, 4, 16
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, Hkv, D), jnp.float32)
    lengths = jnp.asarray([1, 17, 48])       # ragged; 17 straddles K/4=12
    s = jnp.einsum("bhgd,bkhd->bhgk", q, k)
    mask = jnp.arange(K)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    ref = jnp.einsum("bhgk,bkhd->bhgd", jax.nn.softmax(s, axis=-1), v)

    # axis_name=None: the collectives degenerate to identity
    local = combine_split_softmax(s, v)
    assert float(jnp.abs(local - ref).max()) < 1e-5

    # K split over a 4-wide model axis: partials combine across shards
    mesh = jax.make_mesh((4,), ("model",))
    sharded = shard_map(
        lambda sl, vl: combine_split_softmax(sl, vl, "model"),
        mesh=mesh,
        in_specs=(P(None, None, None, "model"), P(None, "model")),
        out_specs=P(), check_vma=False)(s, v)
    assert float(jnp.abs(sharded - ref).max()) < 1e-5


def test_sanitize_spec_warns_and_reports_dropped_dims():
    """Regression: sanitize_spec silently replaced an intended shard with
    full replication (a capacity bug at scale — e.g. vocab=504 or
    n_kv_heads=8 on a 16-wide model axis). It must warn once per distinct
    drop and report the replicated dim indices through ``dropped``."""
    import types
    import warnings
    from repro.distributed.sharding import (ShardingDropWarning,
                                            _SANITIZE_WARNED)
    # sanitize_spec only reads mesh.shape[axis]; a 16-wide stub exercises
    # the axis widths the 8-device test pool cannot build
    mesh16 = types.SimpleNamespace(shape={"data": 1, "model": 16})
    _SANITIZE_WARNED.clear()
    dropped = []
    with pytest.warns(ShardingDropWarning, match="REPLICATE"):
        spec = sanitize_spec(P("model"), (504,), mesh16, dropped=dropped)
    assert spec == P() and dropped == [0]    # 504 % 16 != 0 -> replicated
    # one-time: the SAME drop does not warn again (no per-step log spam)
    with warnings.catch_warnings():
        warnings.simplefilter("error", ShardingDropWarning)
        assert sanitize_spec(P("model"), (504,), mesh16) == P()
    # mixed spec: only the indivisible KV-head dim (8 % 16) drops, and the
    # caller is told exactly which one
    _SANITIZE_WARNED.clear()
    dropped = []
    with pytest.warns(ShardingDropWarning):
        spec = sanitize_spec(P(None, None, None, "model", None),
                             (2, 4, 16, 8, 32), mesh16, dropped=dropped)
    assert dropped == [3] and spec == P()
    # divisible dims keep their sharding and report nothing
    dropped = []
    assert sanitize_spec(P("model"), (512,), mesh16,
                         dropped=dropped) == P("model")
    assert dropped == []


def test_sharding_contexts_isolated_across_interleaved_streams():
    """Regression: ``activation_sharding`` / ``split_kv_enabled`` are
    contextvar-backed, so two logically-concurrent streams (e.g. a TP
    serving thread next to a training trace) interleaved in any order each
    observe ONLY their own setting — a module-global flag would leak the
    last writer's value across both."""
    import contextvars
    from repro.distributed.sharding import (_ACT_SPEC, activation_sharding,
                                            split_kv_active, split_kv_enabled)
    spec_a, spec_b = P("data"), P("model")
    ctx_a, ctx_b = contextvars.copy_context(), contextvars.copy_context()

    def enter(cm):
        cm.__enter__()
        return cm

    # interleave: A enters, B enters different values, both re-checked
    a_act = ctx_a.run(enter, activation_sharding(spec_a))
    assert ctx_b.run(_ACT_SPEC.get) is None          # B unaffected by A
    ctx_b.run(enter, activation_sharding(spec_b))
    b_kv = ctx_b.run(enter, split_kv_enabled(True))
    assert ctx_a.run(_ACT_SPEC.get) == spec_a        # A keeps its own
    assert ctx_b.run(_ACT_SPEC.get) == spec_b
    assert ctx_a.run(split_kv_active) is False       # B's split-KV private
    assert ctx_b.run(split_kv_active) is True
    # A exits while B is still inside: B's values must survive
    ctx_a.run(a_act.__exit__, None, None, None)
    assert ctx_a.run(_ACT_SPEC.get) is None
    assert ctx_b.run(_ACT_SPEC.get) == spec_b
    assert ctx_b.run(split_kv_active) is True
    ctx_b.run(b_kv.__exit__, None, None, None)
    assert ctx_b.run(split_kv_active) is False
    # this test's contexts are copies: the suite's root context untouched
    assert _ACT_SPEC.get() is None and split_kv_active() is False
