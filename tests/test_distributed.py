"""Distribution tests on an 8-host-device mesh: sharding rules, small-mesh
compiles, pipeline parallelism, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_smoke_config
from repro.distributed.compression import (compress_grads_with_feedback,
                                           compressed_psum, init_error)
from repro.distributed.sharding import (batch_sharding, cache_specs,
                                        param_specs, sanitize_spec)
from repro.distributed.compat import set_mesh, shard_map
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_step_and_specs
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


def small_mesh():
    return make_host_mesh(2, 4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_all_leaves(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(RNG))
    mesh = small_mesh()
    specs = param_specs(shapes, mesh)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    # every spec valid for its leaf (divisibility sanitized)
    for s, sp in zip(flat_shapes, flat_specs):
        for dim, ax in zip(s.shape, list(sp)):
            if ax is not None:
                n = np.prod([mesh.shape[a] for a in
                             ((ax,) if isinstance(ax, str) else ax)])
                assert dim % n == 0


def test_sanitize_spec():
    mesh = small_mesh()        # model axis = 4
    assert sanitize_spec(P("model"), (503,), mesh) == P()       # 503 % 4 != 0
    assert sanitize_spec(P("model"), (512,), mesh) == P("model")
    assert sanitize_spec(P(("data",), "model"), (1, 8), mesh) == P(None, "model")


def _compile_cells():
    """Supported (arch, shape) cells only — the support gate is static
    config knowledge (e.g. encoder-only hubert has no decode shapes), so
    unsupported combinations are excluded at collection instead of
    producing perpetual runtime skips. ``test_cell_support_gate`` pins the
    gate itself."""
    from repro.configs.base import cell_is_supported
    cells = []
    for arch in ("llama3-8b", "qwen2-moe-a2.7b", "zamba2-2.7b", "rwkv6-3b",
                 "hubert-xlarge"):
        for shape_name in ("train_4k", "decode_32k"):
            cfg = get_smoke_config(arch)
            shape = dataclasses.replace(SHAPES[shape_name], seq_len=64,
                                        global_batch=4)
            if cell_is_supported(cfg, shape)[0]:
                cells.append((arch, shape_name))
    return cells


def test_cell_support_gate():
    """The only gated-out compile cell is encoder-only hubert x decode
    (no autoregressive path to compile) — if the gate widens, the compile
    grid above must be revisited, so pin it."""
    from repro.configs.base import cell_is_supported
    cells = _compile_cells()
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert len(cells) == 9
    ok, reason = cell_is_supported(
        get_smoke_config("hubert-xlarge"),
        dataclasses.replace(SHAPES["decode_32k"], seq_len=64, global_batch=4))
    assert not ok and reason


@pytest.mark.parametrize("arch,shape_name", _compile_cells())
def test_small_mesh_compile(arch, shape_name):
    """The dry-run pipeline end-to-end on a 2x4 host mesh, reduced shapes."""
    from repro.distributed.sharding import activation_sharding
    cfg = get_smoke_config(arch)
    shape = dataclasses.replace(SHAPES[shape_name], seq_len=64, global_batch=4)
    mesh = small_mesh()
    with set_mesh(mesh):
        jf, args, act_spec = make_step_and_specs(cfg, mesh, shape)
        with activation_sharding(act_spec):
            compiled = jf.lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_pipeline_parallel_matches_serial():
    from repro.distributed.pipeline import make_pipeline_forward
    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    ks = jax.random.split(RNG, 2)
    Ws = jax.random.normal(ks[0], (n_stages, 1, d, d)) / np.sqrt(d)
    x = jax.random.normal(ks[1], (n_micro, mb, d))

    def layer_fn(w, h):
        return jnp.tanh(h @ w[0])

    pipe = make_pipeline_forward(layer_fn, n_stages, n_micro, mesh)
    with set_mesh(mesh):
        y = pipe(Ws, x)
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ Ws[s, 0])
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-5


def test_compression_error_feedback_unbiased():
    """EF carries the residual: sum of compressed grads -> sum of true grads."""
    g = jax.random.normal(RNG, (256,)) * 0.01
    err = jnp.zeros((256,))
    acc_c = jnp.zeros((256,))
    for i in range(50):
        comp, err = compress_grads_with_feedback({"g": g}, {"g": err["g"] if
                                                 isinstance(err, dict) else err})
        err = err["g"]
        acc_c = acc_c + comp["g"]
    acc_true = 50 * g
    rel = float(jnp.linalg.norm(acc_c - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01        # residual bounded, not accumulating


def test_compressed_psum_close_to_exact():
    mesh = jax.make_mesh((8,), ("d",))
    x = jax.random.normal(RNG, (8, 128))

    @jax.jit
    def f(x):
        return shard_map(lambda xs: compressed_psum(xs, "d"),
                             mesh=mesh, in_specs=P("d"),
                             out_specs=P("d"))(x)
    with set_mesh(mesh):
        y = f(x)
    exact = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
    rel = float(jnp.max(jnp.abs(y - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.05        # int8 quantized reduction


def test_split_kv_decode_matches_oracle():
    """Mesh split-KV flash-decoding == single-device decode oracle."""
    from repro.distributed.split_kv import split_kv_decode_update_attend
    from repro.kernels.decode_attention.ref import decode_attention_ref
    mesh = small_mesh()
    B, Smax, Hq, Hkv, D = 4, 64, 8, 2, 16
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
    kn = jax.random.normal(ks[1], (B, 1, Hkv, D), jnp.float32)
    vn = jax.random.normal(ks[2], (B, 1, Hkv, D), jnp.float32)
    kc = jax.random.normal(ks[3], (B, Smax, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[4], (B, Smax, Hkv, D), jnp.float32)
    for pos in (0, 15, 16, 37, 63):      # includes shard boundaries
        idx = jnp.asarray(pos, jnp.int32)
        with set_mesh(mesh):
            out, ck, cv = jax.jit(split_kv_decode_update_attend)(
                q, kn, vn, kc, vc, idx)
        kc2 = kc.at[:, pos].set(kn[:, 0])
        vc2 = vc.at[:, pos].set(vn[:, 0])
        ref = decode_attention_ref(q[:, 0], kc2, vc2, pos + 1)
        assert float(jnp.abs(out[:, 0] - ref).max()) < 1e-5, pos
        assert float(jnp.abs(np.asarray(ck) - np.asarray(kc2)).max()) == 0.0
