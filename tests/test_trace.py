"""Tracing subsystem (serving/trace.py): byte-deterministic artifacts under
FakeClock, span/flow structural integrity, zero-overhead-when-off, exact
Prometheus exposition, plan-drift accounting, and the stats() schema
contract the reconciliation rides on."""
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.serving.scheduler import PagedBatcher, Request
from repro.serving.telemetry import FakeClock
from repro.serving.trace import (
    DriftAggregator, MetricsRegistry, NULL_TRACER, STATS_COUNTER_KEYS,
    STATS_GAUGE_KEYS, Tracer, counter_reconciliation)

_ROOT = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "check_trace", _ROOT / "scripts" / "check_trace.py")
check_trace = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace)


def _cost_model(kind, predicted_us):
    # deterministic virtual cost: the solver's prediction, floored at 10us
    return max(predicted_us, 10.0) * 1e-6


def _traced_run(cfg, params, *, seed=0, n_req=3, new_tokens=6, **kw):
    """One deterministic PagedBatcher run under a traced FakeClock.
    Returns (batcher, tracer, outputs)."""
    tracer = Tracer(FakeClock(), cost_model=_cost_model)
    pb = PagedBatcher(cfg, params, num_blocks=25, block_size=16,
                      max_blocks_per_seq=6, decode_width=3, buckets=(32, 64),
                      cache_dtype=np.float32, tracer=tracer, **kw)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12 + 7 * i
                                        ).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n_req)]
    for r in reqs:
        pb.submit(r)
    for _ in range(10_000):
        if not pb.busy:
            break
        pb.step()
    pb.kv.assert_drained()
    return pb, tracer, [list(r.output) for r in reqs]


# ------------------------------------------------------------ determinism --

def test_trace_bitwise_identical_across_reruns(smoke_model, tmp_path):
    """The headline determinism contract: two identical runs under FakeClock
    produce byte-identical Chrome trace files and Prometheus snapshots."""
    cfg, _, params = smoke_model
    paths = []
    for i in range(2):
        _, tracer, _ = _traced_run(cfg, params, sync="device", window=2,
                                   engine_mode="hetero-tensor")
        p = tracer.save_chrome(tmp_path / f"trace{i}.json")
        (tmp_path / f"metrics{i}.prom").write_text(tracer.to_prometheus())
        paths.append(p)
    b0, b1 = (p.read_bytes() for p in paths)
    assert b0 == b1
    m0, m1 = ((tmp_path / f"metrics{i}.prom").read_bytes() for i in range(2))
    assert m0 == m1
    # and the artifact is structurally valid (monotone ts, paired B/E,
    # resolvable flows) per the CI checker
    assert check_trace.validate(json.loads(b0.decode())) == []


def test_traced_run_matches_untraced_tokens(smoke_model):
    """Tracing is observation only: token output with the tracer attached
    is identical to the default (NULL_TRACER) run, and the default run
    records nothing."""
    cfg, _, params = smoke_model
    _, tracer, traced_out = _traced_run(cfg, params, sync="device", window=2)

    pb = PagedBatcher(cfg, params, num_blocks=25, block_size=16,
                      max_blocks_per_seq=6, decode_width=3, buckets=(32, 64),
                      cache_dtype=np.float32, sync="device", window=2)
    assert pb.tracer is NULL_TRACER
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12 + 7 * i
                                        ).astype(np.int32),
                    max_new_tokens=6)
            for i in range(3)]
    for r in reqs:
        pb.submit(r)
    while pb.busy:
        pb.step()
    assert [list(r.output) for r in reqs] == traced_out
    assert tracer.n_events > 0


def test_null_tracer_records_nothing():
    """Every NullTracer hook is a no-op returning a live context."""
    with NULL_TRACER.span("x"):
        with NULL_TRACER.dispatch("y", tags=(("wq", 1, "pad", 3.0, 1),)):
            NULL_TRACER.instant("z")
            NULL_TRACER.request_event("enqueue", 0)
            NULL_TRACER.count("decode_steps")
            NULL_TRACER.gauge("peak_active", 4)
    assert NULL_TRACER.enabled is False


# ------------------------------------------------------- event structure --

def test_span_nesting_and_flow_integrity(smoke_model):
    cfg, _, params = smoke_model
    _, tracer, _ = _traced_run(cfg, params, sync="host",
                               engine_mode="hetero-tensor")
    trace = tracer.to_chrome()
    assert check_trace.validate(trace) == []
    events = trace["traceEvents"]
    names = {e["name"] for e in events if e["ph"] == "B"}
    assert "prefill_chunk" in names and "decode_step" in names
    # every dispatch B carries its solver decisions
    for e in events:
        if e["ph"] == "B" and e.get("cat") == "dispatch" \
                and e["name"] in ("prefill_chunk", "decode_step"):
            decs = e["args"]["decisions"]
            assert decs and all(
                set(d) == {"site", "M", "strategy", "t_us", "count"}
                for d in decs)


def test_request_flow_arrows():
    """Lifecycle -> Chrome flow mapping: 's' at enqueue, 't' mid-life,
    'f' (with bp=e) at finish, id = rid — and the checker resolves it."""
    tr = Tracer(FakeClock())
    for rid in (0, 1):
        tr.request_event("enqueue", rid)
        tr.request_event("admit", rid, track="scheduler")
    tr.request_event("preempt", 1, track="scheduler")
    tr.request_event("resume", 1, track="scheduler")
    for rid in (0, 1):
        tr.request_event("finish", rid)
    trace = tr.to_chrome()
    assert check_trace.validate(trace) == []
    flows = [e for e in trace["traceEvents"] if e["ph"] in ("s", "t", "f")]
    assert [(e["ph"], e["id"]) for e in flows] == [
        ("s", 0), ("t", 0), ("s", 1), ("t", 1), ("t", 1), ("t", 1),
        ("f", 0), ("f", 1)]
    assert all(e["bp"] == "e" for e in flows if e["ph"] == "f")
    # a dangling flow (started, never finished) is a checker violation
    tr2 = Tracer(FakeClock())
    tr2.request_event("enqueue", 7)
    errs = check_trace.validate(tr2.to_chrome())
    assert any("never finished" in e for e in errs)


def test_ring_buffer_bounds_memory():
    clk = FakeClock()
    tr = Tracer(clk, capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    assert len(tr.events) == 8
    assert tr.n_events == 20 and tr.dropped == 12
    assert tr.to_chrome()["otherData"] == {"dropped_events": 12,
                                           "total_events": 20}
    with pytest.raises(ValueError):
        Tracer(clk, capacity=0)


def test_cost_model_advances_fake_clock():
    clk = FakeClock()
    tr = Tracer(clk, cost_model=lambda kind, pred: 0.002)
    with tr.dispatch("decode_step"):
        pass
    assert clk.now() == pytest.approx(0.002)
    b, e = tr.events
    assert (b["ph"], e["ph"]) == ("B", "E")
    assert e["ts"] - b["ts"] == 2000          # 2ms in integer microseconds


# ---------------------------------------------------------------- metrics --

def test_prometheus_snapshot_exact():
    """Pin the exposition format byte-for-byte on a tiny registry."""
    m = MetricsRegistry(buckets=(100.0, 1000.0))
    m.count("decode_steps", 3)
    m.count("dispatches", kind="decode_step")
    m.count("dispatches", 2, kind="prefill_chunk")
    m.gauge("peak_active", 4)
    m.observe("dispatch_us", 50.0, kind="decode_step")
    m.observe("dispatch_us", 500.0, kind="decode_step")
    m.observe("dispatch_us", 5000.0, kind="decode_step")
    assert m.to_prometheus() == (
        "# HELP repro_decode_steps_total decode_steps (counter)\n"
        "# TYPE repro_decode_steps_total counter\n"
        "repro_decode_steps_total 3\n"
        "# HELP repro_dispatches_total dispatches (counter)\n"
        "# TYPE repro_dispatches_total counter\n"
        'repro_dispatches_total{kind="decode_step"} 1\n'
        'repro_dispatches_total{kind="prefill_chunk"} 2\n'
        "# HELP repro_peak_active peak_active (gauge)\n"
        "# TYPE repro_peak_active gauge\n"
        "repro_peak_active 4\n"
        "# HELP repro_dispatch_us dispatch_us (histogram)\n"
        "# TYPE repro_dispatch_us histogram\n"
        'repro_dispatch_us_bucket{kind="decode_step",le="100"} 1\n'
        'repro_dispatch_us_bucket{kind="decode_step",le="1000"} 2\n'
        'repro_dispatch_us_bucket{kind="decode_step",le="+Inf"} 3\n'
        'repro_dispatch_us_sum{kind="decode_step"} 5550\n'
        'repro_dispatch_us_count{kind="decode_step"} 3\n')


def test_metrics_value_lookup():
    m = MetricsRegistry()
    assert m.value("never_touched") == 0
    m.count("a", 2)
    m.count("a", 3)
    m.gauge("g", 7)
    assert m.value("a") == 5 and m.value("g") == 7


# ------------------------------------------------------------- plan drift --

def test_drift_contradiction_flagged():
    """Two strategies at the same (site, M): flag when the one measured
    fastest is not the one predicted fastest, and only then."""
    d = DriftAggregator()
    d.record("wq", 64, "pad", predicted_us=10.0, observed_us=30.0)
    d.record("wq", 64, "split", predicted_us=20.0, observed_us=15.0)
    rep = d.report()
    assert len(rep["rows"]) == 2 and d.n_decisions == 2
    (c,) = rep["contradictions"]
    assert c["planned"] == "pad" and c["faster"] == "split"
    assert "CONTRADICTION" in d.format_table()

    agree = DriftAggregator()
    agree.record("wq", 64, "pad", predicted_us=10.0, observed_us=12.0)
    agree.record("wq", 64, "split", predicted_us=20.0, observed_us=25.0)
    assert agree.report()["contradictions"] == []
    # a single observed strategy has no ordering to contradict
    solo = DriftAggregator()
    solo.record("wq", 64, "pad", predicted_us=10.0, observed_us=99.0)
    assert solo.report()["contradictions"] == []


def test_drift_rows_cover_every_plan_site(smoke_model):
    """Acceptance criterion: a (site, M, strategy) residual row exists for
    every decision the engine-mode run exercised."""
    cfg, _, params = smoke_model
    pb, tracer, _ = _traced_run(cfg, params, sync="device", window=2,
                                engine_mode="hetero-tensor")
    plan_sites = {s for (s, _) in pb.ctx.plan.decisions}
    rows = tracer.drift.report()["rows"]
    assert {r["site"] for r in rows} == plan_sites
    for r in rows:
        assert r["n"] > 0 and r["predicted_us"] > 0
        assert r["residual_us"] == pytest.approx(
            r["observed_us"] - r["predicted_us"])
    assert "decision rows" in tracer.drift.format_table()


def test_dispatch_prediction_and_nearest_m_lookup(smoke_model):
    from repro.core.engine import build_hetero_ctx, dispatch_prediction
    cfg, _, params = smoke_model
    ctx = build_hetero_ctx(cfg, mode="hetero-tensor")
    plan = ctx.plan
    # nearest-M: an unsolved M resolves to the closest solved one
    (site, some_m), dec = next(iter(plan.decisions.items()))
    assert plan.lookup(site, some_m) is dec
    ms = sorted({m for (s, m) in plan.decisions if s == site})
    nearest = plan.lookup(site, ms[-1] + 10_000)
    assert nearest is plan.decisions[(site, ms[-1])]
    assert plan.lookup("no_such_site", 1) is None
    # predictions: every plan site tagged, count folds in layers and steps
    tags, total = dispatch_prediction(plan, cfg, m=1, steps=4)
    assert {t[0] for t in tags} == {s for (s, _) in plan.decisions}
    for (s, m, strat, t_us, count) in tags:
        assert count == 4 * (1 if s == "head" else cfg.n_layers)
    assert total == pytest.approx(sum(t * c for (_, _, _, t, c) in tags))
    # no plan -> no tags, zero cost (the disabled / xla-mode path)
    assert dispatch_prediction(None, cfg, m=1) == ((), 0.0)


# --------------------------------------------------------- stats contract --

def test_stats_schema_collision_free(smoke_model):
    """The merged AsyncServer.stats() namespace: batcher base keys, prefix
    keys, spec keys and ingress keys never collide, and value types are
    stable — the schema the exposition and reconciliation depend on."""
    base = {"tp", "peak_active", "decode_dispatches", "decode_steps",
            "prefill_dispatches", "fused_steps", "preemptions",
            "total_dispatches"}
    prefix = {"prefix_hits", "prefix_tokens_reused", "evictions",
              "cow_copies", "cached_blocks"}
    spec = {"spec_k", "draft_model", "spec_rounds", "drafted_tokens",
            "accepted_tokens", "acceptance_rate", "verify_dispatches",
            "draft_dispatches", "target_dispatches"}
    ingress = {"ingress_ticks", "ingress_preemptions", "ingress_deferrals"}
    for a, b in ((base, prefix), (base, spec), (base, ingress),
                 (prefix, spec), (prefix, ingress), (spec, ingress)):
        assert not (a & b), f"stats key collision: {a & b}"
    # every mirrored counter/gauge key must belong to exactly one group
    mirrored = set(STATS_COUNTER_KEYS) | set(STATS_GAUGE_KEYS)
    assert mirrored <= (base | prefix | spec | ingress)

    cfg, _, params = smoke_model
    pb, _, _ = _traced_run(cfg, params, sync="host", prefix_cache=True)
    s = pb.stats()
    assert base | prefix <= set(s)
    for k, v in s.items():
        assert isinstance(v, (int, float, str, np.integer)), (k, type(v))
        if k in STATS_COUNTER_KEYS or k in STATS_GAUGE_KEYS:
            assert isinstance(v, (int, np.integer)), (k, type(v))


def test_counter_reconciliation_exact(smoke_model):
    """Tracer counters mirror the scheduler's python counters exactly on a
    real run — and a deliberate skew is caught."""
    cfg, _, params = smoke_model
    pb, tracer, _ = _traced_run(cfg, params, sync="device", window=2,
                                prefix_cache=True)
    assert counter_reconciliation(tracer, pb.stats()) == {}
    # B-event counts agree with the dispatch counters, per kind
    by_kind: dict = {}
    for e in tracer.events:
        if e["ph"] == "B" and e.get("cat") == "dispatch":
            by_kind[e["name"]] = by_kind.get(e["name"], 0) + 1
    s = pb.stats()
    assert by_kind.get("prefill_chunk", 0) == s["prefill_dispatches"]
    decode_kinds = ("decode_step", "decode_window", "mixed_step",
                    "mixed_window", "paged_verify")
    assert sum(by_kind.get(k, 0) for k in decode_kinds) \
        == s["decode_dispatches"]
    # a skewed ledger is reported, not hidden
    skewed = dict(s)
    skewed["decode_steps"] += 1
    mism = counter_reconciliation(tracer, skewed)
    assert set(mism) == {"decode_steps"}
