"""Unified kernel conformance harness.

ONE parameterized parity grid (dtype x shape, with ragged-M / odd-K edge
cases — tests/conftest.py::CONFORMANCE_CASES) applied uniformly to all four
Pallas kernel packages against their pure-jnp ``ref.py`` oracles:

  * ``hetero_matmul``    — f32/bf16/f16 matmul, int8 ``quant_matmul_pallas``,
                           packed-int4 W4A16 ``q4_matmul_pallas``
  * ``flash_attention``  — causal GQA prefill attention
  * ``decode_attention`` — split-KV valid-prefix decode attention
  * ``ssm_scan``         — SSD (Mamba2) chunk step

Each package's adapter maps the canonical (M, K, N) case onto its operand
shapes and applies the SAME pad-to-128 policy production uses (HeteroCtx
stage padding / the ops-layer head-dim pad), so the ragged/odd cases
exercise exactly the alignment path the engine routes misaligned shapes
through. Interpret mode on CPU; parity is the contract, not wall time.
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import (CONFORMANCE_CASES, CONFORMANCE_DTYPES, DTYPE_TOL,
                      pad_to, rel_err)

RNG = jax.random.PRNGKey(0)
ALIGN = 128


# ------------------------------------------------------------- adapters ----
# adapter(case, dtype) -> (kernel output, oracle output, tolerance)

def _matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import mxu_matmul
    from repro.kernels.hetero_matmul.ref import matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), dtype)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)
    y = mxu_matmul(xp, wp)[:case.M, :case.N]
    return y, matmul_ref(x, w), DTYPE_TOL[dtype]


def _quant_matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import (mxu_quant_matmul,
                                                 quantize_weight)
    from repro.kernels.hetero_matmul.ref import quant_matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), jnp.float32)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)
    wq, s = quantize_weight(wp)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    y = mxu_quant_matmul(xp, wq, s)[:case.M, :case.N]
    ref = quant_matmul_ref(x, wq[:case.K, :case.N], s[:case.N],
                           out_dtype=x.dtype)
    return y, ref, DTYPE_TOL[dtype]


def _q4_matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 mxu_q4_matmul,
                                                 quantize_weight_int4)
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), jnp.float32)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)       # even K guaranteed
    wq4, s = quantize_weight_int4(wp)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    y = mxu_q4_matmul(xp, wq4, s)[:case.M, :case.N]
    ref = (x.astype(jnp.float32)
           @ dequant_int4_ref(wq4, s)[:case.K, :case.N]).astype(x.dtype)
    return y, ref, DTYPE_TOL[dtype]


def _flash_attention(case, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    S, D = case.M, min(case.K, ALIGN)     # ragged S; odd K -> odd head dim
    Hq, Hkv = 4, 2
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (1, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (1, S, Hkv, D), dtype)
    # ragged S: pad queries AND keys to the block grid. Causal masking makes
    # the padded keys invisible to the real queries; padded query rows are
    # sliced off — the same policy the serving path uses for ragged chunks.
    qp, kp, vp = (pad_to(a, 64, 1) for a in (q, k, v))
    o = flash_attention(qp, kp, vp, causal=True, block_q=64,
                        block_k=64)[:, :S]
    err = rel_err(o, attention_ref(q, k, v, causal=True))
    if S % 64 == 0:
        # block-aligned S needs no key padding, so the NON-causal mask path
        # is exercised too (padded keys would contaminate a non-causal
        # softmax, hence only on aligned cases — incl. odd-D via odd K)
        o_nc = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        err = max(err, rel_err(o_nc, attention_ref(q, k, v, causal=False)))
    return err, 0.0, DTYPE_TOL[dtype]


def _decode_attention(case, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    Smax, length = 256, min(case.M, 256)  # ragged valid prefix
    D = min(case.K, ALIGN)                # odd K -> odd head dim (ops pads)
    Hq, Hkv = 4, 2
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (2, Smax, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (2, Smax, Hkv, D), dtype)
    o = decode_attention(q, kc, vc, length, block_k=128)
    return o, decode_attention_ref(q, kc, vc, length), DTYPE_TOL[dtype]


def _ssm_scan(case, dtype):
    from repro.kernels.ssm_scan.kernel import ssd_chunk_pallas
    from repro.kernels.ssm_scan.ref import ssd_chunk_ref
    L, nh, hd, N = case.M, 2, 64, 64      # ragged chunk length
    ks = jax.random.split(RNG, 5)
    cast = lambda a: a.astype(dtype).astype(jnp.float32)  # noqa: E731
    # kernel contract is f32 operands (ops.py casts); the dtype axis
    # quantizes the inputs so every grid cell still runs per-dtype data
    xb = cast(jax.random.normal(ks[0], (2, L, nh, hd)) * 0.5)
    B_ = cast(jax.random.normal(ks[1], (2, L, N)) * 0.5)
    C_ = cast(jax.random.normal(ks[2], (2, L, N)) * 0.5)
    seg = -jnp.cumsum(jnp.abs(cast(jax.random.normal(ks[3], (2, L, nh)))
                              * 0.1), 1)
    S_prev = cast(jax.random.normal(ks[4], (2, nh, hd, N)) * 0.3)
    y1, s1 = ssd_chunk_pallas(xb, B_, C_, seg, S_prev)
    y2, s2 = ssd_chunk_ref(xb, B_, C_, seg, S_prev)
    err = max(rel_err(y1, y2), rel_err(s1, s2))
    return err, 0.0, 1e-4                 # pre-reduced: compare err to tol


KERNELS = {
    "hetero_matmul/mxu": _matmul,
    "hetero_matmul/quant_int8": _quant_matmul,
    "hetero_matmul/q4_w4a16": _q4_matmul,
    "flash_attention": _flash_attention,
    "decode_attention": _decode_attention,
    "ssm_scan": _ssm_scan,
}


@pytest.mark.tier1
@pytest.mark.parametrize("dtype", CONFORMANCE_DTYPES)
@pytest.mark.parametrize("case", CONFORMANCE_CASES, ids=lambda c: c.name)
@pytest.mark.parametrize("kernel", KERNELS)
def test_kernel_conformance(kernel, case, dtype):
    """Every kernel package x every shape edge case x every dtype: the
    Pallas kernel must agree with its ref.py oracle within the dtype's
    output-rounding tolerance."""
    got, want, tol = KERNELS[kernel](case, dtype)
    if isinstance(got, float):            # adapter pre-reduced to an error
        assert got < tol, f"{kernel}/{case.name}/{dtype}: err {got} >= {tol}"
    else:
        err = rel_err(got, want)
        assert err < tol, f"{kernel}/{case.name}/{dtype}: err {err} >= {tol}"


# ------------------------------------------------- quantization accuracy ---
# (kernel-independent properties of the two weight formats; the parity of
# the kernels against the dequant oracle is covered by the grid above)

@pytest.mark.tier1
def test_int8_quantization_error_bound():
    from repro.kernels.hetero_matmul.ops import quantize_weight
    from repro.kernels.hetero_matmul.ref import matmul_ref, quant_matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (128, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32)
    wq, s = quantize_weight(w)
    assert rel_err(quant_matmul_ref(x, wq, s), matmul_ref(x, w)) < 0.05


@pytest.mark.tier1
def test_int4_quantization_error_bound():
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 quantize_weight_int4)
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (128, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32)
    wq4, s = quantize_weight_int4(w)
    assert rel_err(x @ dequant_int4_ref(wq4, s), x @ w) < 0.15
