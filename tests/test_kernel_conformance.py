"""Unified kernel conformance harness.

ONE parameterized parity grid (dtype x shape, with ragged-M / odd-K edge
cases — tests/conftest.py::CONFORMANCE_CASES) applied uniformly to all four
Pallas kernel packages against their pure-jnp ``ref.py`` oracles:

  * ``hetero_matmul``    — f32/bf16/f16 matmul, int8 ``quant_matmul_pallas``,
                           packed-int4 W4A16 ``q4_matmul_pallas``
  * ``flash_attention``  — causal GQA prefill attention
  * ``decode_attention`` — split-KV valid-prefix decode attention
  * ``ssm_scan``         — SSD (Mamba2) chunk step

Each package's adapter maps the canonical (M, K, N) case onto its operand
shapes and applies the SAME pad-to-128 policy production uses (HeteroCtx
stage padding / the ops-layer head-dim pad), so the ragged/odd cases
exercise exactly the alignment path the engine routes misaligned shapes
through. Interpret mode on CPU; parity is the contract, not wall time.
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import (CONFORMANCE_CASES, CONFORMANCE_DTYPES, DTYPE_TOL,
                      QUANT_SERVING_CHECKS, pad_to, rel_err)

RNG = jax.random.PRNGKey(0)
ALIGN = 128


# ------------------------------------------------------------- adapters ----
# adapter(case, dtype) -> (kernel output, oracle output, tolerance)

def _matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import mxu_matmul
    from repro.kernels.hetero_matmul.ref import matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), dtype)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)
    y = mxu_matmul(xp, wp)[:case.M, :case.N]
    return y, matmul_ref(x, w), DTYPE_TOL[dtype]


def _quant_matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import (mxu_quant_matmul,
                                                 quantize_weight)
    from repro.kernels.hetero_matmul.ref import quant_matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), jnp.float32)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)
    wq, s = quantize_weight(wp)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    y = mxu_quant_matmul(xp, wq, s)[:case.M, :case.N]
    ref = quant_matmul_ref(x, wq[:case.K, :case.N], s[:case.N],
                           out_dtype=x.dtype)
    return y, ref, DTYPE_TOL[dtype]


def _q4_matmul(case, dtype):
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 mxu_q4_matmul,
                                                 quantize_weight_int4)
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (case.M, case.K), dtype)
    w = jax.random.normal(k2, (case.K, case.N), jnp.float32)
    wp = pad_to(pad_to(w, ALIGN, 0), ALIGN, 1)       # even K guaranteed
    wq4, s = quantize_weight_int4(wp)
    xp = pad_to(pad_to(x, ALIGN, 0), ALIGN, 1)
    y = mxu_q4_matmul(xp, wq4, s)[:case.M, :case.N]
    ref = (x.astype(jnp.float32)
           @ dequant_int4_ref(wq4, s)[:case.K, :case.N]).astype(x.dtype)
    return y, ref, DTYPE_TOL[dtype]


def _flash_attention(case, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    S, D = case.M, min(case.K, ALIGN)     # ragged S; odd K -> odd head dim
    Hq, Hkv = 4, 2
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (1, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (1, S, Hkv, D), dtype)
    # ragged S: pad queries AND keys to the block grid. Causal masking makes
    # the padded keys invisible to the real queries; padded query rows are
    # sliced off — the same policy the serving path uses for ragged chunks.
    qp, kp, vp = (pad_to(a, 64, 1) for a in (q, k, v))
    o = flash_attention(qp, kp, vp, causal=True, block_q=64,
                        block_k=64)[:, :S]
    err = rel_err(o, attention_ref(q, k, v, causal=True))
    if S % 64 == 0:
        # block-aligned S needs no key padding, so the NON-causal mask path
        # is exercised too (padded keys would contaminate a non-causal
        # softmax, hence only on aligned cases — incl. odd-D via odd K)
        o_nc = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        err = max(err, rel_err(o_nc, attention_ref(q, k, v, causal=False)))
    return err, 0.0, DTYPE_TOL[dtype]


def _decode_attention(case, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    Smax, length = 256, min(case.M, 256)  # ragged valid prefix
    D = min(case.K, ALIGN)                # odd K -> odd head dim (ops pads)
    Hq, Hkv = 4, 2
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (2, Smax, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (2, Smax, Hkv, D), dtype)
    o = decode_attention(q, kc, vc, length, block_k=128)
    return o, decode_attention_ref(q, kc, vc, length), DTYPE_TOL[dtype]


def _ssm_scan(case, dtype):
    from repro.kernels.ssm_scan.kernel import ssd_chunk_pallas
    from repro.kernels.ssm_scan.ref import ssd_chunk_ref
    L, nh, hd, N = case.M, 2, 64, 64      # ragged chunk length
    ks = jax.random.split(RNG, 5)
    cast = lambda a: a.astype(dtype).astype(jnp.float32)  # noqa: E731
    # kernel contract is f32 operands (ops.py casts); the dtype axis
    # quantizes the inputs so every grid cell still runs per-dtype data
    xb = cast(jax.random.normal(ks[0], (2, L, nh, hd)) * 0.5)
    B_ = cast(jax.random.normal(ks[1], (2, L, N)) * 0.5)
    C_ = cast(jax.random.normal(ks[2], (2, L, N)) * 0.5)
    seg = -jnp.cumsum(jnp.abs(cast(jax.random.normal(ks[3], (2, L, nh)))
                              * 0.1), 1)
    S_prev = cast(jax.random.normal(ks[4], (2, nh, hd, N)) * 0.3)
    y1, s1 = ssd_chunk_pallas(xb, B_, C_, seg, S_prev)
    y2, s2 = ssd_chunk_ref(xb, B_, C_, seg, S_prev)
    err = max(rel_err(y1, y2), rel_err(s1, s2))
    return err, 0.0, 1e-4                 # pre-reduced: compare err to tol


KERNELS = {
    "hetero_matmul/mxu": _matmul,
    "hetero_matmul/quant_int8": _quant_matmul,
    "hetero_matmul/q4_w4a16": _q4_matmul,
    "flash_attention": _flash_attention,
    "decode_attention": _decode_attention,
    "ssm_scan": _ssm_scan,
}


@pytest.mark.tier1
@pytest.mark.parametrize("dtype", CONFORMANCE_DTYPES)
@pytest.mark.parametrize("case", CONFORMANCE_CASES, ids=lambda c: c.name)
@pytest.mark.parametrize("kernel", KERNELS)
def test_kernel_conformance(kernel, case, dtype):
    """Every kernel package x every shape edge case x every dtype: the
    Pallas kernel must agree with its ref.py oracle within the dtype's
    output-rounding tolerance."""
    got, want, tol = KERNELS[kernel](case, dtype)
    if isinstance(got, float):            # adapter pre-reduced to an error
        assert got < tol, f"{kernel}/{case.name}/{dtype}: err {got} >= {tol}"
    else:
        err = rel_err(got, want)
        assert err < tol, f"{kernel}/{case.name}/{dtype}: err {err} >= {tol}"


# ------------------------------------------------- quantization accuracy ---
# (kernel-independent properties of the two weight formats; the parity of
# the kernels against the dequant oracle is covered by the grid above)

@pytest.mark.tier1
def test_int8_quantization_error_bound():
    from repro.kernels.hetero_matmul.ops import quantize_weight
    from repro.kernels.hetero_matmul.ref import matmul_ref, quant_matmul_ref
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (128, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32)
    wq, s = quantize_weight(w)
    assert rel_err(quant_matmul_ref(x, wq, s), matmul_ref(x, w)) < 0.05


@pytest.mark.tier1
def test_int4_quantization_error_bound():
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 quantize_weight_int4)
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (128, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32)
    wq4, s = quantize_weight_int4(w)
    assert rel_err(x @ dequant_int4_ref(wq4, s), x @ w) < 0.15


def _unpack_int4(wq4):
    lo = (jnp.left_shift(wq4, 4) >> 4).astype(jnp.int32)
    hi = (wq4 >> 4).astype(jnp.int32)
    k2, n = wq4.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


@pytest.mark.tier1
def test_int4_roundtrip_exact_codes_odd_k():
    """Odd-K int4 regression: values that are exact multiples of the
    asymmetric-range scale must round-trip to their exact codes, including
    the -8 code the [-8, 7] range reserves, with the padded half-row
    invisible to the dequant slice."""
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 quantize_weight_int4)
    w = 0.5 * jnp.array([[-8.0], [-4.0], [-6.0], [-2.0], [7.0]])  # K=5 odd
    wq4, s = quantize_weight_int4(w)
    assert wq4.shape == (3, 1)          # ceil(5/2) packed rows
    assert float(s[0]) == 0.5           # neg-heavy column: scale = amax/8
    codes = _unpack_int4(wq4)[:5, 0]
    assert codes.tolist() == [-8, -4, -6, -2, 7]
    assert jnp.array_equal(dequant_int4_ref(wq4, s, 5), w)


@pytest.mark.tier1
def test_int4_all_negative_channel_roundtrip():
    """All-negative channel regression: amax sits on the negative side, so
    the asymmetric scale amax/8 makes every exact multiple representable —
    the pre-fix symmetric amax/7 scale could not round-trip the minimum."""
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 quantize_weight_int4)
    w = -0.25 * jnp.arange(1.0, 9.0)[:, None]          # K=8, all negative
    wq4, s = quantize_weight_int4(w)
    assert float(s[0]) == 0.25                         # scale = 2.0 / 8
    assert _unpack_int4(wq4)[:, 0].tolist() == [-1, -2, -3, -4,
                                                -5, -6, -7, -8]
    assert jnp.array_equal(dequant_int4_ref(wq4, s, 8), w)


@pytest.mark.tier1
def test_quant_zero_channel_edge():
    """An all-zero output channel must quantize to scale-fallback codes of
    exactly 0 (no 0/0), in both weight formats."""
    from repro.kernels.hetero_matmul.ops import (dequant_int4_ref,
                                                 quantize_weight,
                                                 quantize_weight_int4)
    w = jnp.concatenate([jnp.zeros((6, 1)),
                         jax.random.normal(RNG, (6, 1))], axis=1)
    wq, s = quantize_weight(w)
    assert float(s[0]) == 1.0 and not wq[:, 0].any()
    wq4, s4 = quantize_weight_int4(w)
    assert float(s4[0]) == 1.0
    assert not dequant_int4_ref(wq4, s4, 6)[:, 0].any()


@pytest.mark.tier1
def test_int8_max_magnitude_channel_roundtrip():
    """A channel of exact scale multiples (amax hits the +/-127 rails)
    round-trips losslessly through int8."""
    from repro.kernels.hetero_matmul.ops import quantize_weight
    w = 0.02 * jnp.array([[-127.0], [63.0], [-11.0], [127.0]])
    wq, s = quantize_weight(w)
    assert wq[:, 0].tolist() == [-127, 63, -11, 127]
    assert jnp.allclose(wq * s, w, atol=1e-7)


@pytest.mark.tier1
def test_kv_slot_quantization_edges():
    """int8 KV pool scalar quantization: a zero slot stores scale 0 (the
    unwritten-slot marker) and dequantizes to exactly 0; a slot of exact
    scale multiples round-trips losslessly."""
    from repro.models.layers import dequant_kv_ref, quantize_kv_slot
    zero = jnp.zeros((2, 3, 4))
    codes, s = quantize_kv_slot(zero)
    assert not codes.any() and not s.astype(jnp.float32).any()
    assert not dequant_kv_ref(codes, s, jnp.float32).any()
    x = 0.25 * jnp.array([[-127.0, 64.0], [3.0, 127.0]])[None]
    codes, s = quantize_kv_slot(x)
    assert float(s[0]) == 0.25          # exactly representable in bf16
    assert jnp.array_equal(dequant_kv_ref(codes, s, jnp.float32), x)


# ------------------------------------------- quantized serving entry points
# Every serving entry point that can carry quantized weights, held against
# its dequantize-then-fp reference: identical math (and thus tokens) via the
# plan-free fallback, kernel-tolerance parity via the HeteroCtx MXU path.

QUANT_FORMATS = ("int8", "w4a16")
_ENTRY_POINTS = tuple(c for c in QUANT_SERVING_CHECKS
                      if c != "int8_pool_gather")


def _serving_entry(model, cfg, params, entry, ctx=None, kv_quant=None):
    """Run one serving entry point on ragged shapes; returns its logits."""
    B, S, NB, BS = 2, 9, 16, 8                      # ragged S (not a block
    tok = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)   # multiple)
    bt = jnp.array([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
    pool = model.init_paged_cache(num_blocks=NB, block_size=BS,
                                  dtype=jnp.float32, kv_quant=kv_quant)
    logits, pool = model.paged_prefill(params, tok, pool, block_table=bt,
                                       start_index=0, hetero_ctx=ctx)
    if entry == "paged_prefill":
        return logits
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    if entry == "paged_decode_step":
        lg, _ = model.paged_decode_step(params, nxt, pool,
                                        block_tables=bt,
                                        lengths=jnp.array([S, S]),
                                        hetero_ctx=ctx)
        return lg
    if entry == "paged_verify":
        vt = jnp.concatenate([nxt, (nxt + 1) % cfg.vocab_size], axis=1)
        lg, _ = model.paged_verify(params, vt, pool,
                                   block_table=bt,
                                   start_index=jnp.array([S, S]),
                                   hetero_ctx=ctx)
        return lg
    assert entry == "mixed_step"
    chunk = jax.random.randint(jax.random.PRNGKey(5), (1, 5),
                               0, cfg.vocab_size)   # ragged prefill chunk
    pt = jnp.array([[7, 8, 0, 0]], jnp.int32)
    dlg, plg, _ = model.mixed_step(params, nxt, chunk, pool,
                                   decode_tables=bt,
                                   decode_lengths=jnp.array([S, S]),
                                   prefill_table=pt,
                                   prefill_start=jnp.asarray(0, jnp.int32),
                                   hetero_ctx=ctx)
    return jnp.concatenate([dlg[:, -1], plg[:, -1]], axis=0)


@pytest.fixture(scope="module")
def quant_params(smoke_model):
    """Per-format quantized + dequantized-reference params, and the
    weight-quant-planned hetero ctx, shared across the entry-point grid."""
    from repro.core.engine import build_hetero_ctx
    from repro.models.quant import dequantize_params, quantize_params
    cfg, model, params = smoke_model
    out = {}
    for fmt in QUANT_FORMATS:
        qp = quantize_params(params, cfg, fmt)
        out[fmt] = (qp, dequantize_params(qp),
                    build_hetero_ctx(cfg, "hetero-tensor", weight_quant=fmt))
    return out


@pytest.mark.tier1
@pytest.mark.parametrize("fmt", QUANT_FORMATS)
@pytest.mark.parametrize("entry", _ENTRY_POINTS)
def test_quant_serving_entry_fallback_exact(entry, fmt, smoke_model,
                                            quant_params):
    """Plan-free (ctx=None) quantized execution must match the dequantize-
    then-fp reference to fp rounding: both sides run literally the same
    dequantized weight values."""
    cfg, model, _ = smoke_model
    qp, dq, _ = quant_params[fmt]
    got = _serving_entry(model, cfg, qp, entry)
    want = _serving_entry(model, cfg, dq, entry)
    assert rel_err(got, want) < DTYPE_TOL["float32"]


@pytest.mark.tier1
@pytest.mark.parametrize("fmt", QUANT_FORMATS)
@pytest.mark.parametrize("entry", _ENTRY_POINTS)
def test_quant_serving_entry_hetero_kernels(entry, fmt, smoke_model,
                                            quant_params):
    """The solver-planned path (quantized MXU kernels, in-VMEM dequant) must
    agree with the dequantize-then-fp reference within kernel tolerance."""
    cfg, model, _ = smoke_model
    qp, dq, ctx = quant_params[fmt]
    got = _serving_entry(model, cfg, qp, entry, ctx=ctx)
    want = _serving_entry(model, cfg, dq, entry)
    assert rel_err(got, want) < 1e-4


@pytest.mark.tier1
@pytest.mark.parametrize("entry", _ENTRY_POINTS)
def test_int8_pool_gather_conformance(entry, smoke_model):
    """The int8 paged pool (quantize-on-scatter, dequant-on-gather) must
    track the fp pool within the per-slot int8 rounding budget on every
    entry point that reads the pool."""
    cfg, model, params = smoke_model
    want = _serving_entry(model, cfg, params, entry)
    got = _serving_entry(model, cfg, params, entry, kv_quant="int8")
    assert rel_err(got, want) < 0.05


@pytest.mark.tier1
def test_conformance_grid_covers_quant():
    """Meta-test: the conformance grid can only grow. Every quantized
    serving check named in conftest is implemented, both quantized kernel
    adapters sit in the kernel grid, and the per-channel edge-case shape
    is on the case list."""
    assert {"hetero_matmul/quant_int8", "hetero_matmul/q4_w4a16"} <= \
        set(KERNELS)
    assert set(_ENTRY_POINTS) | {"int8_pool_gather"} == \
        set(QUANT_SERVING_CHECKS)
    assert len(QUANT_SERVING_CHECKS) >= 5
    assert "quant_edges" in {c.name for c in CONFORMANCE_CASES}
    assert len(CONFORMANCE_CASES) * len(CONFORMANCE_DTYPES) \
        * len(KERNELS) >= 108
