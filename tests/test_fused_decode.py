"""Hetero-aware paged serving: fused-window (fast-sync) decode vs the
host-synced loop, and solver-planned vs dense-strategy paged prefill.

The contracts under test mirror the engine arms' invariant: fast sync and
solver partitioning are EXECUTION SCHEDULE changes, never numerics changes,
so greedy token streams must match exactly across every arm."""
import jax.numpy as jnp
import numpy as np

from repro.core.engine import build_hetero_ctx
from repro.serving.scheduler import PagedBatcher, Request

# smoke_model: session-scoped fixture from conftest.py


def _ref_generate(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, prompt[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _batcher(cfg, params, **kw):
    kw.setdefault("num_blocks", 33)
    kw.setdefault("block_size", 16)
    kw.setdefault("decode_width", 4)
    kw.setdefault("buckets", (32, 64))
    kw.setdefault("cache_dtype", jnp.float32)
    return PagedBatcher(cfg, params, **kw)


# ------------------------------------------------------ fused-window decode --

def test_fused_window_matches_host_loop(smoke_model):
    """Mixed prompt lengths AND mixed budgets: requests finish at different
    steps inside the same window (budgets 5/9/3/7 with window 4), so every
    window carries a partially-masked lane. Both arms must equal the
    sequential per-request reference token-for-token."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (37, 75, 20, 9)]
    budgets = [5, 9, 3, 7]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=m)
                for i, (p, m) in enumerate(zip(prompts, budgets))]

    host = _batcher(cfg, params, sync="host")
    out_h = host.run(reqs())
    dev = _batcher(cfg, params, sync="device", window=4)
    out_d = dev.run(reqs())

    for h, d, p, m in zip(out_h, out_d, prompts, budgets):
        ref = _ref_generate(model, params, jnp.asarray(p), m)
        assert h.output == ref
        assert d.output == ref
        assert h.done and d.done
    # fused arm: all lanes' budgets fit ceil(max(budget-1)/window) windows
    assert dev.decode_dispatches == 2 and host.decode_dispatches == 8
    assert dev.decode_steps == host.decode_steps == sum(budgets) - len(budgets)
    # pool fully reclaimed (mid-window finishes returned their blocks)
    dev.kv.allocator.check()
    assert dev.kv.allocator.n_free == dev.kv.num_blocks - 1


def test_fused_window_mid_window_eos(smoke_model):
    """EOS sampled mid-window: the lane's remaining steps are masked on
    device, and both arms stop the stream right after the EOS token."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 23).astype(np.int32)
    ref = _ref_generate(model, params, jnp.asarray(prompt), 8)
    # pick an EOS that first appears at step >= 2: genuinely mid-window,
    # with valid tokens both before and (masked) after it
    k = next(i for i in range(2, 7) if ref[i] not in ref[:i])
    eos = ref[k]

    outs = {}
    for sync, kw in (("host", {}), ("device", {"window": 8})):
        pb = _batcher(cfg, params, num_blocks=9, decode_width=1,
                      sync=sync, eos_id=eos, **kw)
        req = pb.run([Request(rid=0, prompt=prompt, max_new_tokens=8)])[0]
        assert req.done
        outs[sync] = req.output
    assert outs["host"] == outs["device"] == ref[:k + 1]


def test_fused_window_eos_at_prefill(smoke_model):
    """EOS as the very first (prefill-sampled) token: no decode dispatch at
    all, on either arm."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    first = _ref_generate(model, params, jnp.asarray(prompt), 1)[0]
    for sync in ("host", "device"):
        pb = _batcher(cfg, params, num_blocks=9, decode_width=1, sync=sync,
                      eos_id=first)
        req = pb.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])[0]
        assert req.done and req.output == [first]
        assert pb.decode_dispatches == 0


def test_fused_window_dispatch_count(smoke_model):
    """The acceptance arithmetic: n budget-limited decode steps cost
    ceil(n / window) dispatches on the fused arm vs n on the host arm."""
    cfg, _, params = smoke_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)

    def one(sync, **kw):
        pb = _batcher(cfg, params, num_blocks=9, decode_width=1, sync=sync,
                      **kw)
        pb.run([Request(rid=0, prompt=prompt, max_new_tokens=9)])
        return pb
    host = one("host")
    dev = one("device", window=4)
    assert host.decode_steps == dev.decode_steps == 8
    assert host.decode_dispatches == 8
    assert dev.decode_dispatches == 2            # ceil(8 / 4)


# -------------------------------------------------- solver-planned prefill --

def _paged_prefill_logits(model, prompt, params, ctx):
    S, BS, NBmax = len(prompt), 16, 8
    pool = model.init_paged_cache(num_blocks=9, block_size=BS,
                                  dtype=jnp.float32)
    table = np.zeros((NBmax,), np.int32)
    nblk = -(-S // BS)
    table[:nblk] = np.arange(1, nblk + 1)
    logits, _ = model.paged_prefill(params, jnp.asarray(prompt)[None], pool,
                                    block_table=jnp.asarray(table)[None],
                                    hetero_ctx=ctx)
    return np.asarray(logits)


def test_solver_planned_prefill_matches_dense(smoke_model):
    """Solver-planned paged prefill vs the dense (no-ctx) strategy: the
    xla arm is BIT-exact (same dot, different dispatch); kernel-path arms
    (mxu / hetero-tensor) accumulate tiles in a different order, so they
    are ULP-close and argmax-identical — the same invariant the engine
    arms assert."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)
    base = _paged_prefill_logits(model, prompt, params, None)

    xla = _paged_prefill_logits(model, prompt, params,
                                build_hetero_ctx(cfg, "xla"))
    assert np.array_equal(base, xla)

    for mode in ("hetero-tensor", "mxu"):
        got = _paged_prefill_logits(model, prompt, params,
                                    build_hetero_ctx(cfg, mode))
        np.testing.assert_allclose(got, base, atol=1e-4, rtol=1e-5)
        assert np.argmax(got[0, -1]) == np.argmax(base[0, -1]), mode


def test_engine_mode_batcher_token_exact(smoke_model):
    """End to end: solver-planned prefill + fused-window decode through the
    batcher generates the same tokens as the dense host-synced baseline."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (37, 70, 21)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]

    base = _batcher(cfg, params, decode_width=3, sync="host").run(reqs())
    hetero = _batcher(cfg, params, decode_width=3, sync="device", window=4,
                      engine_mode="hetero-tensor").run(reqs())
    for b, h, p in zip(base, hetero, prompts):
        ref = _ref_generate(model, params, jnp.asarray(p), 5)
        assert b.output == h.output == ref
