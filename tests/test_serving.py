"""Serving stack: continuous batcher exactness, sampler properties, engine
modes and prefill strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.engine import InferenceEngine
from repro.models import build_model
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import ContinuousBatcher, Request

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def _ref_generate(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, prompt[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_continuous_batcher_matches_sequential(smoke_model):
    cfg, model, params = smoke_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in (37, 75, 20, 130)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    cb = ContinuousBatcher(cfg, params, max_batch=2, max_len=256,
                           buckets=(32, 64))
    cb.cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        cb.cache)
    cb.run(reqs)
    for r in reqs:
        assert r.done
        assert r.output == _ref_generate(model, params, jnp.asarray(r.prompt), 5)


def test_submit_rejects_duplicate_rid_and_empty_prompt(smoke_model):
    """Regression: both batchers used to silently accept a duplicate rid
    (corrupting per-request bookkeeping) and an empty prompt (which can
    never prefill). Both must raise at submit time — and only LIVE rids
    count as duplicates: a finished rid may be reused (preemption resumes
    and multi-wave workloads rely on it)."""
    from repro.serving.scheduler import PagedBatcher
    cfg, model, params = smoke_model
    prompt = np.arange(5, dtype=np.int32)

    cb = ContinuousBatcher(cfg, params, max_batch=2, max_len=64,
                           buckets=(32, 64))
    pb = PagedBatcher(cfg, params, num_blocks=9, block_size=16,
                      max_blocks_per_seq=2, decode_width=2, buckets=(32, 64),
                      cache_dtype=jnp.float32)
    for b in (cb, pb):
        with pytest.raises(ValueError, match="empty prompt"):
            b.submit(Request(rid=0, prompt=np.zeros((0,), np.int32),
                             max_new_tokens=2))
        b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
        with pytest.raises(ValueError, match="duplicate"):
            b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))

    # in-flight (admitted, not just queued) rids are duplicates too...
    pb.step()
    assert pb.busy            # still mid-decode after one step
    with pytest.raises(ValueError, match="duplicate"):
        pb.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    # ...but a FINISHED rid is reusable
    while pb.busy:
        pb.step()
    pb.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    while pb.busy:
        pb.step()
    pb.kv.assert_drained()


def test_sampler_greedy_is_argmax():
    logits = jax.random.normal(RNG, (4, 100))
    t = sample(logits, RNG, SamplerConfig(temperature=0.0))
    assert (t == jnp.argmax(logits, -1)).all()


@pytest.mark.parametrize("k,seed", [(1, 0), (3, 11), (7, 42), (13, 7),
                                    (20, 999)])
def test_sampler_topk_support(k, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (2, 64))
    t = sample(logits, jax.random.PRNGKey(seed + 1),
               SamplerConfig(temperature=1.0, top_k=k))
    # sampled token must be among the top-k of each row
    topk = jnp.argsort(logits, -1)[:, -k:]
    for b in range(2):
        assert int(t[b]) in np.asarray(topk[b])


@pytest.mark.parametrize("mode", ["xla", "hetero-layer", "hetero-tensor"])
def test_engine_modes_generate(mode):
    cfg = get_smoke_config("llama3-8b")
    eng = InferenceEngine(cfg, mode=mode, max_len=256)
    prompt = jax.random.randint(RNG, (1, 90), 0, cfg.vocab_size)
    toks = eng.generate(prompt, max_new_tokens=4)
    assert toks.shape == (1, 4)


@pytest.mark.parametrize("strategy", ["online-prepare", "padding", "pipe",
                                      "hetero"])
def test_engine_prefill_strategies_same_output(strategy, smoke_model):
    """All dynamic-shape strategies must produce identical generations —
    they differ only in execution schedule (paper Fig 14 arms)."""
    cfg, model, params = smoke_model
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 77), 0,
                                cfg.vocab_size)
    eng = InferenceEngine(cfg, params, mode="xla",
                          prefill_strategy=strategy,
                          buckets=(32, 64), max_len=256)
    toks = np.asarray(eng.generate(prompt, max_new_tokens=4))
    ref = _ref_generate(model, params, prompt[0], 4)
    assert toks[0].tolist() == ref, strategy


def test_engine_fast_sync_equivalence(smoke_model):
    cfg, model, params = smoke_model
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 40), 0,
                                cfg.vocab_size)
    outs = []
    for fast in (True, False):
        eng = InferenceEngine(cfg, params, mode="xla", fast_sync=fast,
                              buckets=(32, 64), max_len=128)
        outs.append(np.asarray(eng.generate(prompt, max_new_tokens=5)))
    assert (outs[0] == outs[1]).all()


def test_engine_modes_identical_outputs():
    """The four engine arms differ ONLY in execution schedule: all must
    generate identical tokens (partitioning never changes numerics)."""
    cfg = get_smoke_config("llama3-8b")
    prompt = jax.random.randint(jax.random.PRNGKey(9), (1, 90), 0,
                                cfg.vocab_size)
    outs = []
    for mode in ("xla", "mxu", "hetero-layer", "hetero-tensor"):
        eng = InferenceEngine(cfg, mode=mode, max_len=256)
        outs.append(np.asarray(eng.generate(prompt, max_new_tokens=3)))
    for o in outs[1:]:
        assert (o == outs[0]).all()
