"""Telemetry unit tests: percentile math, per-request trace metrics, clock
behavior and report reproducibility — all on hand-built event streams with
known answers, zero model, zero wall clock."""
import asyncio
import math

import pytest

from repro.serving.telemetry import (Clock, FakeClock, MonotonicClock,
                                     RequestTrace, Telemetry, percentile,
                                     summarize)


# ------------------------------------------------------------- percentiles --

@pytest.mark.tier1
def test_percentile_linear_interpolation_exact():
    # 0..99: pos = 99 * q/100, linear between neighbors
    xs = list(range(100))
    assert percentile(xs, 50) == pytest.approx(49.5)
    assert percentile(xs, 95) == pytest.approx(94.05)
    assert percentile(xs, 99) == pytest.approx(98.01)
    assert percentile(xs, 0) == 0.0
    assert percentile(xs, 100) == 99.0


@pytest.mark.tier1
def test_percentile_two_points():
    assert percentile([0.0, 10.0], 50) == pytest.approx(5.0)
    assert percentile([0.0, 10.0], 95) == pytest.approx(9.5)
    assert percentile([0.0, 10.0], 99) == pytest.approx(9.9)


@pytest.mark.tier1
def test_percentile_order_independent():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 50) == 3.0
    assert percentile(sorted(xs, reverse=True), 50) == 3.0


@pytest.mark.tier1
def test_percentile_edge_cases():
    assert percentile([], 50) is None
    # a singleton is every percentile of itself
    for q in (0, 50, 95, 99, 100):
        assert percentile([7.25], q) == 7.25
    with pytest.raises(ValueError):
        percentile([1.0], -1)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


@pytest.mark.tier1
def test_summarize_shape_and_values():
    s = summarize([2.0, 4.0, 6.0])
    assert s["n"] == 3 and s["mean"] == 4.0 and s["max"] == 6.0
    assert s["p50"] == 4.0
    empty = summarize([])
    assert empty == {"n": 0, "mean": None, "p50": None, "p95": None,
                     "p99": None, "max": None}


# ------------------------------------------------------------------ traces --

@pytest.mark.tier1
def test_trace_metrics_hand_computed():
    clock = FakeClock()
    tel = Telemetry(clock)
    tel.on_enqueue(0, at=0.0)
    tel.on_admit(0, at=2.0)
    tel.on_token(0, at=3.0)      # first token: ttft = 3 - 0
    tel.on_token(0, at=4.0)
    tel.on_token(0, at=6.0)      # tpot = (6 - 3) / 2 = 1.5, excludes TTFT
    tel.on_finish(0, at=6.0)
    tr = tel.traces[0]
    assert tr.ttft == pytest.approx(3.0)
    assert tr.queue_delay == pytest.approx(2.0)   # admit - enqueue
    assert tr.tpot == pytest.approx(1.5)
    assert tr.n_tokens == 3 and tr.finished


@pytest.mark.tier1
def test_tpot_undefined_below_two_tokens():
    tel = Telemetry(FakeClock())
    tel.on_enqueue(0, at=0.0)
    assert tel.traces[0].tpot is None and tel.traces[0].ttft is None
    tel.on_token(0, at=5.0)
    assert tel.traces[0].tpot is None            # one token: no gap yet
    assert tel.traces[0].ttft == 5.0


@pytest.mark.tier1
def test_readmit_preserves_first_admit_stamp():
    tel = Telemetry(FakeClock())
    tel.on_enqueue(0, at=1.0)
    tel.on_admit(0, at=2.0)
    tel.on_preempt(0)
    tel.on_admit(0, at=9.0)                      # resume: NOT the anchor
    tr = tel.traces[0]
    assert tr.queue_delay == pytest.approx(1.0)
    assert tr.readmits == 1 and tr.preemptions == 1


@pytest.mark.tier1
def test_event_contract_violations_raise():
    tel = Telemetry(FakeClock())
    tel.on_enqueue(0, at=0.0)
    with pytest.raises(ValueError, match="already enqueued"):
        tel.on_enqueue(0, at=1.0)
    with pytest.raises(KeyError, match="never enqueued"):
        tel.on_token(99)
    tel.on_finish(0, at=1.0)
    with pytest.raises(ValueError, match="finished twice"):
        tel.on_finish(0, at=2.0)


# ------------------------------------------------------------------ report --

def _three_request_stream(tel: Telemetry) -> None:
    """Hand-built stream with known aggregates:
    rid 0: enq 0, admit 1, tokens 2/3/4, finish 4  -> ttft 2, qd 1, tpot 1
    rid 1: enq 0, admit 3, tokens 5/9,   finish 9  -> ttft 5, qd 3, tpot 4
    rid 2: enq 1, admit 2, token  4,     finish 4  -> ttft 3, qd 1, no tpot
    """
    for rid, enq in ((0, 0.0), (1, 0.0), (2, 1.0)):
        tel.on_enqueue(rid, at=enq)
    tel.on_admit(0, at=1.0)
    tel.on_admit(1, at=3.0)
    tel.on_admit(2, at=2.0)
    for rid, ts in ((0, (2.0, 3.0, 4.0)), (1, (5.0, 9.0)), (2, (4.0,))):
        for t in ts:
            tel.on_token(rid, at=t)
    tel.on_finish(0, at=4.0)
    tel.on_finish(1, at=9.0)
    tel.on_finish(2, at=4.0)


@pytest.mark.tier1
def test_report_aggregates_hand_computed():
    tel = Telemetry(FakeClock())
    _three_request_stream(tel)
    rep = tel.report(slo_ms=4000.0)
    assert rep["n_requests"] == rep["n_finished"] == 3
    assert rep["n_tokens"] == 6
    assert rep["ttft_ms"]["p50"] == pytest.approx(3000.0)
    assert rep["ttft_ms"]["max"] == pytest.approx(5000.0)
    assert rep["queue_delay_ms"]["p50"] == pytest.approx(1000.0)
    assert rep["tpot_ms"]["n"] == 2                 # rid 2 has no gap
    assert rep["tpot_ms"]["mean"] == pytest.approx(2500.0)
    assert rep["makespan_s"] == pytest.approx(9.0)  # min enq 0 .. max fin 9
    assert rep["throughput_tok_s"] == pytest.approx(6 / 9)
    # SLO 4000 ms: rids 0 (2s) and 2 (3s) meet it, rid 1 (5s) misses
    assert rep["slo_attainment"] == pytest.approx(2 / 3)
    assert rep["goodput_req_s"] == pytest.approx(2 / 9)


@pytest.mark.tier1
def test_report_without_slo_counts_all_finished():
    tel = Telemetry(FakeClock())
    _three_request_stream(tel)
    rep = tel.report()
    assert rep["slo_ms"] is None
    assert rep["slo_attainment"] == 1.0
    assert rep["goodput_req_s"] == pytest.approx(3 / 9)


@pytest.mark.tier1
def test_report_empty_and_unfinished():
    tel = Telemetry(FakeClock())
    assert tel.report()["n_requests"] == 0
    assert tel.report()["makespan_s"] is None
    tel.on_enqueue(0, at=0.0)                      # enqueued, never finished
    rep = tel.report()
    assert rep["n_requests"] == 1 and rep["n_finished"] == 0
    assert rep["goodput_req_s"] is None


@pytest.mark.tier1
def test_report_bitwise_reproducible():
    reps = []
    for _ in range(2):
        tel = Telemetry(FakeClock())
        _three_request_stream(tel)
        reps.append(tel.report(slo_ms=100.0))
    assert reps[0] == reps[1]


# ------------------------------------------------------------------ clocks --

@pytest.mark.tier1
def test_fake_clock_advance_and_sleep():
    clock = FakeClock(start=5.0)
    assert clock.now() == 5.0
    clock.advance(2.5)
    assert clock.now() == 7.5
    with pytest.raises(ValueError, match="backwards"):
        clock.advance(-1.0)

    async def drive():
        await clock.sleep(3.0)
        await clock.sleep(-1.0)       # clamped, never goes backwards
        return clock.now()

    assert asyncio.run(drive()) == 10.5


@pytest.mark.tier1
def test_monotonic_clock_is_a_clock_and_moves_forward():
    clock = MonotonicClock()
    assert isinstance(clock, Clock) and isinstance(FakeClock(), Clock)
    t0 = clock.now()
    assert clock.now() >= t0
    assert not hasattr(clock, "advance")   # the ingress gate relies on this

    async def drive():                     # zero-sleep: yields, no real wait
        await clock.sleep(0.0)

    asyncio.run(drive())


@pytest.mark.tier1
def test_telemetry_stamps_from_injected_clock():
    clock = FakeClock()
    tel = Telemetry(clock)
    tel.on_enqueue(0)                      # at= omitted -> clock.now()
    clock.advance(4.0)
    tel.on_admit(0)
    assert tel.traces[0].queue_delay == pytest.approx(4.0)
    assert math.isclose(tel.traces[0].enqueue_t, 0.0)


@pytest.mark.tier1
def test_trace_defaults():
    tr = RequestTrace(rid=3, priority=1)
    assert not tr.finished and tr.ttft is None and tr.queue_delay is None
    assert tr.token_ts == [] and tr.readmits == 0
