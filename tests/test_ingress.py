"""Open-loop ingress tests: arrival-generator determinism, the streaming
contract (incremental, in order, exactly one terminal event), watermark
backpressure, priority preemption with recompute-on-resume, and stall
detection — all under FakeClock, zero real sleeps."""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.ingress import (AsyncServer, arrival_times,
                                   burst_arrivals, open_loop_workload,
                                   poisson_arrivals)
from repro.serving.scheduler import ContinuousBatcher, PagedBatcher
from repro.serving.telemetry import FakeClock, MonotonicClock

BS = 16
STEP = 1e-3                   # virtual seconds per scheduler tick


# ------------------------------------------------------------- generators --

@pytest.mark.tier1
def test_poisson_arrivals_deterministic_and_monotone():
    a = poisson_arrivals(10.0, 50, seed=3)
    b = poisson_arrivals(10.0, 50, seed=3)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) > 0) and a[0] > 0
    assert not np.array_equal(a, poisson_arrivals(10.0, 50, seed=4))
    # long-run rate: mean gap ~ 1/rate (law of large numbers, wide net)
    gaps = np.diff(poisson_arrivals(10.0, 4000, seed=0))
    assert abs(gaps.mean() - 0.1) < 0.01


@pytest.mark.tier1
def test_burst_arrivals_same_long_run_rate_but_clustered():
    xs = burst_arrivals(10.0, 4000, seed=0, burst_size=4, duty=0.2)
    assert np.all(np.diff(xs) > 0)
    assert abs(np.diff(xs).mean() - 0.1) < 0.01     # same mean rate...
    gaps = np.diff(xs)
    # ...but bimodal: within-burst gaps are ~duty/rate, far below the mean
    assert np.median(gaps) < 0.5 * gaps.mean()
    np.testing.assert_array_equal(xs, burst_arrivals(10.0, 4000, seed=0,
                                                     burst_size=4, duty=0.2))


@pytest.mark.tier1
def test_arrival_generator_validation_and_dispatch():
    np.testing.assert_array_equal(arrival_times("poisson", 5.0, 8, seed=1),
                                  poisson_arrivals(5.0, 8, seed=1))
    np.testing.assert_array_equal(arrival_times("burst", 5.0, 8, seed=1),
                                  burst_arrivals(5.0, 8, seed=1))
    with pytest.raises(ValueError, match="unknown arrival"):
        arrival_times("uniform", 5.0, 8)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(0.0, 8)
    with pytest.raises(ValueError, match="rate"):
        burst_arrivals(-1.0, 8)
    with pytest.raises(ValueError, match="duty"):
        burst_arrivals(5.0, 8, duty=1.0)
    with pytest.raises(ValueError, match="burst_size"):
        burst_arrivals(5.0, 8, burst_size=0)


# -------------------------------------------------------------- harnessing --

def _ref(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _paged(cfg, params, *, num_blocks, max_blocks=4, width=3, **kw):
    from repro.serving.sampler import SamplerConfig
    return PagedBatcher(cfg, params, num_blocks=num_blocks, block_size=BS,
                        max_blocks_per_seq=max_blocks, decode_width=width,
                        buckets=(32, 64), cache_dtype=jnp.float32,
                        sampler=SamplerConfig(), **kw)


# -------------------------------------------------------------- validation --

@pytest.mark.tier1
def test_submit_and_config_validation(smoke_model):
    cfg, model, params = smoke_model
    pb = _paged(cfg, params, num_blocks=9)
    server = AsyncServer(pb, clock=FakeClock())
    with pytest.raises(ValueError, match="non-empty"):
        server.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="non-empty"):
        server.submit(np.zeros((2, 3), np.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        server.submit([1, 2, 3], max_new_tokens=0)
    server.submit([1, 2, 3], rid=7)
    with pytest.raises(ValueError, match="duplicate"):
        server.submit([4, 5], rid=7)

    with pytest.raises(TypeError, match="unsupported batcher"):
        AsyncServer(object())
    with pytest.raises(ValueError, match="advanceable"):
        AsyncServer(_paged(cfg, params, num_blocks=9),
                    clock=MonotonicClock(), step_time_s=STEP)
    cb = ContinuousBatcher(cfg, params, max_batch=2, max_len=64,
                           buckets=(32, 64))
    with pytest.raises(ValueError, match="paged"):
        AsyncServer(cb, admit_watermark=2)


# --------------------------------------------------------------- streaming --

@pytest.mark.tier1
def test_streaming_incremental_in_order_terminal_once(smoke_model):
    """Tokens reach the async consumer AS they are produced — successive
    tokens carry later virtual timestamps — in order, and the stream ends
    with exactly one terminal event."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    n = 5
    ref = _ref(model, params, prompt, n)
    pb = _paged(cfg, params, num_blocks=9)
    clock = FakeClock()
    server = AsyncServer(pb, clock=clock, step_time_s=STEP)

    async def drive():
        handle = server.submit(prompt, max_new_tokens=n)
        seen = []

        async def consume():
            async for tok in handle:
                seen.append((tok, clock.now()))

        consumer = asyncio.create_task(consume())
        await server.run()
        await consumer
        return handle, seen

    handle, seen = asyncio.run(drive())
    assert [t for t, _ in seen] == ref == handle.tokens
    stamps = [s for _, s in seen]
    # incremental: the consumer observes each token IN the virtual tick
    # that produced it (stamps equal the production-side telemetry stamps),
    # spread across multiple ticks — NOT one batch at the end of the run
    assert stamps == server.telemetry.traces[0].token_ts
    assert all(b >= a for a, b in zip(stamps, stamps[1:]))
    assert len(set(stamps)) >= 3
    assert handle.done and handle.terminal_events == 1
    pb.kv.assert_drained()

    async def reiterate():        # a drained, finished stream just closes
        return [tok async for tok in handle]

    assert asyncio.run(reiterate()) == []


@pytest.mark.tier1
def test_stream_contract_violations_raise(smoke_model):
    cfg, model, params = smoke_model
    server = AsyncServer(_paged(cfg, params, num_blocks=9),
                         clock=FakeClock())
    h = server.submit([1, 2, 3], max_new_tokens=1)
    h._put_token(5)
    h._finish()
    with pytest.raises(RuntimeError, match="after finish"):
        h._put_token(6)
    with pytest.raises(RuntimeError, match="finished twice"):
        h._finish()


@pytest.mark.tier1
def test_open_loop_enqueue_stamped_at_scheduled_time(smoke_model):
    """Arrivals are stamped at their SCHEDULED time even when the server
    is mid-batch when they land — that lateness is queueing delay, and the
    telemetry must see it."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (6, 9, 4)]
    budgets = [3, 4, 3]
    refs = [_ref(model, params, p, m) for p, m in zip(prompts, budgets)]
    times = poisson_arrivals(400.0, 3, seed=2)
    pb = _paged(cfg, params, num_blocks=13, width=2)
    server = AsyncServer(pb, clock=FakeClock(), step_time_s=STEP)
    handles = server.run_sync(open_loop_workload(prompts, budgets, times))
    for h, ref in zip(handles, refs):
        assert h.tokens == ref and h.terminal_events == 1
    for rid, t in enumerate(times):
        assert server.telemetry.traces[rid].enqueue_t == pytest.approx(t)
        assert server.telemetry.traces[rid].queue_delay >= 0
    pb.kv.assert_drained()


@pytest.mark.tier1
def test_dense_batcher_open_loop(smoke_model):
    """The ingress is batcher-agnostic: the dense ContinuousBatcher serves
    the same open-loop stream (no watermark/preemption, slot-gated only)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (5, 8, 11)]
    budgets = [3, 2, 4]
    refs = [_ref(model, params, p, m) for p, m in zip(prompts, budgets)]
    cb = ContinuousBatcher(cfg, params, max_batch=2, max_len=64,
                           buckets=(32, 64))
    server = AsyncServer(cb, clock=FakeClock(), step_time_s=STEP)
    handles = server.run_sync(open_loop_workload(
        prompts, budgets, poisson_arrivals(300.0, 3, seed=9)))
    for h, ref in zip(handles, refs):
        assert h.tokens == ref and h.terminal_events == 1
    assert server.ticks > 0


# ------------------------------------------------- backpressure/preemption --

@pytest.mark.tier1
def test_watermark_defers_admission_until_blocks_free(smoke_model):
    """One usable block: the second request must wait for the first to
    drain — deferral count rises, nobody is preempted (same priority), and
    both outputs stay token-identical."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
               for _ in range(2)]
    budgets = [4, 4]
    refs = [_ref(model, params, p, m) for p, m in zip(prompts, budgets)]
    pb = _paged(cfg, params, num_blocks=2, max_blocks=1, width=2)
    server = AsyncServer(pb, clock=FakeClock(), step_time_s=STEP)
    handles = server.run_sync(open_loop_workload(
        prompts, budgets, [0.0, 0.0]))
    for h, ref in zip(handles, refs):
        assert h.tokens == ref
    assert server.deferrals > 0
    assert server.preemptions == 0
    pb.kv.assert_drained()


@pytest.mark.tier1
def test_priority_preempts_and_resumes_token_identical(smoke_model):
    """A blocked high-priority arrival evicts the youngest low-priority
    lane; the victim resumes later (prompt + emitted tokens, remaining
    budget) and its FULL stream is bit-identical to the never-preempted
    reference — recompute-on-resume is invisible to the client."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(33)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]
    budgets = [6, 6, 4]
    refs = [_ref(model, params, p, m) for p, m in zip(prompts, budgets)]
    # 2 usable blocks, 2 lanes: the low-prio pair fills the pool; the
    # high-prio request lands mid-decode and can only run by eviction
    pb = _paged(cfg, params, num_blocks=3, max_blocks=1, width=2)
    server = AsyncServer(pb, clock=FakeClock(), step_time_s=STEP)
    handles = server.run_sync(open_loop_workload(
        prompts, budgets, [0.0, 0.0, 2.5 * STEP], [0, 0, 1]))
    for h, ref in zip(handles, refs):
        assert h.tokens == ref and h.terminal_events == 1, h.rid
    assert server.preemptions == 1
    assert pb.preemptions == 1           # the batcher-side counter agrees
    victim = server.telemetry.traces[1]  # youngest low-prio lane (rid 1)
    assert victim.preemptions == 1 and victim.readmits == 1
    assert server.telemetry.traces[2].preemptions == 0
    pb.kv.assert_drained()


@pytest.mark.tier1
def test_preempt_api_validation(smoke_model):
    cfg, model, params = smoke_model
    pb = _paged(cfg, params, num_blocks=9)
    with pytest.raises(ValueError, match="idle lane"):
        pb.preempt(0)


@pytest.mark.tier1
def test_stall_detection_raises(smoke_model):
    """A request that can NEVER admit (needs more blocks than any sequence
    may hold) must fail loudly, not spin forever."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 2 * BS).astype(np.int32)
    pb = _paged(cfg, params, num_blocks=9, max_blocks=1)
    server = AsyncServer(pb, clock=FakeClock(), step_time_s=STEP)
    with pytest.raises(RuntimeError, match="stalled"):
        server.run_sync(open_loop_workload([prompt], [4], [0.0]))
