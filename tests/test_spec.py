"""Speculative-decoding subsystem tests (serving/spec.py + the VERIFY
solver site class + paged_verify + batcher spec mode).

The load-bearing invariant everywhere: greedy verification is LOSSLESS —
whatever the draft model proposes, the emitted stream must equal per-token
greedy decoding of the target. Drafting only changes how many target
dispatches the stream costs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.engine import build_hetero_ctx, build_plan
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionPlan, PartitionSolver
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request
from repro.serving.sampler import SamplerConfig
from repro.serving.spec import SpecConfig, SpecDecoder

# smoke_model: session-scoped fixture from conftest.py


def _indep_draft_cfg():
    return get_smoke_config("smollm-135m").with_(param_dtype="float32",
                                                 compute_dtype="float32")


def _ref_generate(model, params, prompt, n, eos_id=None):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    while len(out) < n and not (eos_id is not None and out[-1] == eos_id):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


# ------------------------------------------------------------ paged_verify --

@pytest.mark.tier1
def test_paged_verify_matches_sequential_decode_logits(smoke_model):
    """One K+1-position verify dispatch must reproduce the per-position
    logits (argmax-identical, numerically close) of feeding the same
    tokens through paged_decode_step one at a time — the property that
    makes acceptance decisions equal to sequential greedy decode."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(2)
    S, K, BS = 21, 3, 16
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, S), jnp.int32)
    tokens = rng.integers(0, cfg.vocab_size, K + 1).astype(np.int32)

    def fresh(n_blocks=9):
        pool = model.init_paged_cache(num_blocks=n_blocks, block_size=BS,
                                      dtype=jnp.float32)
        table = np.zeros((8,), np.int32)
        table[:4] = np.arange(1, 5)          # covers S + K + 1 positions
        _, pool = model.paged_prefill(params, prompt[None], pool,
                                      block_table=jnp.asarray(table)[None])
        return pool, jnp.asarray(table)[None]

    pool, bt = fresh()
    ver_logits, _ = model.paged_verify(
        params, jnp.asarray(tokens)[None], pool, block_table=bt,
        start_index=jnp.asarray([S], jnp.int32))

    pool, bt = fresh()
    seq_logits = []
    for j, t in enumerate(tokens):
        lg, pool = model.paged_decode_step(
            params, jnp.asarray([[t]], jnp.int32), pool, block_tables=bt,
            lengths=jnp.asarray([S + j], jnp.int32))
        seq_logits.append(np.asarray(lg[0, 0]))
    seq_logits = np.stack(seq_logits)

    ver = np.asarray(ver_logits[0])
    assert (ver.argmax(-1) == seq_logits.argmax(-1)).all()
    np.testing.assert_allclose(ver, seq_logits, rtol=1e-5, atol=1e-5)


@pytest.mark.tier1
def test_paged_verify_scalar_start_index(smoke_model):
    """Scalar start_index (uniform batch) broadcasts like paged_prefill's."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, 10), jnp.int32)
    pool = model.init_paged_cache(num_blocks=5, block_size=16,
                                  dtype=jnp.float32)
    table = np.zeros((4,), np.int32)
    table[:1] = [1]
    _, pool = model.paged_prefill(params, prompt[None], pool,
                                  block_table=jnp.asarray(table)[None])
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 3)), jnp.int32)
    a, _ = model.paged_verify(params, toks, dict(pool),
                              block_table=jnp.asarray(table)[None],
                              start_index=jnp.asarray(10, jnp.int32))
    b, _ = model.paged_verify(params, toks, dict(pool),
                              block_table=jnp.asarray(table)[None],
                              start_index=jnp.asarray([10], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- SpecDecoder --

@pytest.mark.tier1
@pytest.mark.parametrize("sync,self_draft",
                         [("host", True), ("host", False),
                          ("device", True), ("device", False)])
def test_spec_decoder_matches_reference(smoke_model, sync, self_draft):
    """Single-stream spec decoding is bit-identical to sequential greedy
    decode for both sync arms, with a perfect (self) draft and with an
    independent random-init draft."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(1)
    spec = SpecConfig(k=3) if self_draft else \
        SpecConfig(k=3, draft=_indep_draft_cfg())
    sd = SpecDecoder(cfg, params, spec=spec, max_len=128, sync=sync,
                     cache_dtype=jnp.float32)
    for S, n in ((23, 11), (40, 6)):
        prompt = rng.integers(0, cfg.vocab_size, S).astype(np.int32)
        assert sd.generate(prompt, n) == _ref_generate(model, params,
                                                       prompt, n)
    sd.kv.assert_drained()               # every request closed cleanly
    st = sd.stats()
    assert st["verify_dispatches"] > 0
    if self_draft:
        assert st["acceptance_rate"] == 1.0
        assert st["target_dispatches"] < st["emitted_tokens"]


@pytest.mark.tier1
def test_spec_decoder_long_generation_crosses_blocks(smoke_model):
    """Regression: generation long enough to grow several blocks mid-decode
    must stay bit-identical — the device block table has to be
    re-snapshotted every round, or newly-grown positions alias into the
    null block and collide modulo block_size."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    sd = SpecDecoder(cfg, params, spec=SpecConfig(k=3), max_len=200,
                     block_size=16, cache_dtype=jnp.float32)
    n = 100                                    # ~6 blocks grown mid-decode
    assert sd.generate(prompt, n) == _ref_generate(model, params, prompt, n)
    sd.kv.assert_drained()


@pytest.mark.tier1
def test_spec_decoder_eos_cut(smoke_model):
    """An EOS inside an accepted run must cut the stream mid-round exactly
    where sequential decode would stop."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
    free = _ref_generate(model, params, prompt, 10)
    eos = free[4]                         # force a stop mid-stream
    ref = _ref_generate(model, params, prompt, 10, eos_id=eos)
    sd = SpecDecoder(cfg, params, spec=SpecConfig(k=4), max_len=128,
                     eos_id=eos, cache_dtype=jnp.float32)
    assert sd.generate(prompt, 10) == ref
    sd.kv.assert_drained()


def test_spec_config_validation(smoke_model):
    cfg, _, params = smoke_model
    with pytest.raises(ValueError, match="k must be >= 1"):
        SpecConfig(k=0).resolve_draft(cfg)
    with pytest.raises(NotImplementedError, match="greedy"):
        SpecConfig(greedy=False).resolve_draft(cfg)
    with pytest.raises(ValueError, match="token space"):
        SpecConfig(draft=cfg.with_(vocab_size=512)).resolve_draft(cfg)
    with pytest.raises(ValueError, match="attention-family"):
        SpecConfig(draft=get_smoke_config("rwkv6-3b").with_(
            vocab_size=cfg.vocab_size)).resolve_draft(cfg)
    # name resolution goes through the config registry
    assert SpecConfig(draft="smollm-135m",
                      smoke=True).resolve_draft(cfg).name == "smollm-smoke"
    with pytest.raises(ValueError, match="mutually exclusive"):
        PagedBatcher(cfg, params, spec=2, mixed_batch=True,
                     cache_dtype=jnp.float32)
    with pytest.raises(ValueError, match="greedy"):
        PagedBatcher(cfg, params, spec=2,
                     sampler=SamplerConfig(temperature=0.7),
                     cache_dtype=jnp.float32)


# ------------------------------------------------------- batcher spec mode --

@pytest.mark.tier1
def test_spec_batcher_fewer_target_dispatches(smoke_model):
    """Self-draft spec mode emits the baseline's exact streams with
    strictly fewer target dispatches, and the unified stats() counters are
    mutually consistent."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (37, 20, 50)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=9)
                for i, p in enumerate(prompts)]

    kw = dict(num_blocks=25, block_size=16, max_blocks_per_seq=5,
              decode_width=3, buckets=(32, 64), cache_dtype=jnp.float32)
    base = PagedBatcher(cfg, params, sync="host", **kw)
    rb = base.run(reqs())
    pb = PagedBatcher(cfg, params, sync="host", spec=SpecConfig(k=3), **kw)
    rs = pb.run(reqs())
    assert all(a.output == b.output for a, b in zip(rb, rs))
    pb.kv.assert_drained()
    st, bs = pb.stats(), base.stats()
    assert st["target_dispatches"] < bs["total_dispatches"]
    assert st["acceptance_rate"] == 1.0
    assert st["verify_dispatches"] == st["decode_dispatches"]
    assert st["decode_steps"] == bs["decode_steps"]
    assert st["drafted_tokens"] == st["spec_rounds"] * 3


@pytest.mark.tier1
def test_spec_batcher_engine_mode_verify_planned(smoke_model):
    """spec + engine_mode: verification matmuls run the solver's VERIFY
    decisions through the HeteroCtx — still token-identical (partitioning
    is an execution schedule, never a numerics change)."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (33, 12)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=7)
                for i, p in enumerate(prompts)]

    kw = dict(num_blocks=16, block_size=16, max_blocks_per_seq=4,
              decode_width=2, buckets=(32, 64), cache_dtype=jnp.float32)
    base = PagedBatcher(cfg, params, sync="host", **kw)
    rb = base.run(reqs())
    pb = PagedBatcher(cfg, params, sync="host", spec=SpecConfig(k=2),
                      engine_mode="hetero-tensor", **kw)
    rs = pb.run(reqs())
    assert all(a.output == b.output for a, b in zip(rb, rs))
    pb.kv.assert_drained()
    # the ctx carries VERIFY decisions for this scheduler's (k, lanes)
    assert pb.ctx.plan.verify_decision("wq", 2, 2) is not None


@pytest.mark.tier1
def test_spec_batcher_eos_mid_round(smoke_model):
    """EOS emitted inside an accepted run finishes the lane exactly where
    the non-spec arm does."""
    cfg, model, params = smoke_model
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 19).astype(np.int32)
    free = _ref_generate(model, params, prompt, 12)
    eos = free[5]

    def reqs():
        return [Request(rid=0, prompt=prompt, max_new_tokens=12)]

    kw = dict(num_blocks=9, block_size=16, max_blocks_per_seq=4,
              decode_width=1, buckets=(32, 64), cache_dtype=jnp.float32,
              eos_id=eos)
    base = PagedBatcher(cfg, params, sync="host", **kw)
    rb = base.run(reqs())
    pb = PagedBatcher(cfg, params, sync="host", spec=SpecConfig(k=4), **kw)
    rs = pb.run(reqs())
    assert rb[0].output == rs[0].output
    assert rs[0].output[-1] == eos and eos not in rs[0].output[:-1]
    pb.kv.assert_drained()


# ------------------------------------------------------ VERIFY solver class --

def test_solver_verify_decisions_and_roundtrip():
    """build_plan(verify_ks=...) populates every site's VERIFY decisions in
    their own key space, save/load round-trips them, and the analytic gain
    of one M=lanes*(K+1) dispatch over K+1 M=lanes dispatches is positive
    under host-sync dispatch costs."""
    cfg = get_smoke_config("llama3-8b")
    table, plan = build_plan(cfg, sync_mode="host",
                             verify_ks=((4, 8), (2, 1)))
    for site in table.sites:
        for key in ((4, 8), (2, 1)):
            dec = plan.verify_decision(site, *key)
            assert dec is not None and "verify[k=" in dec.ratio
            assert dec.M == key[1] * (key[0] + 1)
        assert plan.verify_decision(site, 3, 1) is None   # unsolved shape
    path = None
    import tempfile
    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as f:
        path = f.name
    plan.save(path)
    loaded = PartitionPlan.load(path)
    assert loaded.verify_decisions == plan.verify_decisions
    solver = PartitionSolver(profile_analytic(cfg), sync_mode="host")
    assert solver.verify_gain_us("w_gate", 4, lanes=8) > 0
    # a verify decision never beats the unconstrained best for the same M:
    # it IS the same search, keyed for the scheduler-chosen shape
    d_v = solver.solve_verify("w_gate", 4, lanes=8)
    d_m = solver.solve_site("w_gate", 8 * 5)
    assert d_v.t_us == d_m.t_us and d_v.strategy == d_m.strategy


def test_hetero_ctx_for_verify_resolves_verify_decisions():
    """for_verify(k, lanes) views the same plan through the VERIFY key
    space; matmul output is unchanged (schedule, not numerics)."""
    cfg = get_smoke_config("llama3-8b")
    ctx = build_hetero_ctx(cfg, "hetero-tensor", sync_mode="host",
                           verify_ks=((2, 3),))
    vctx = ctx.for_verify(2, 3)
    assert vctx.verify_key == (2, 3) and ctx.verify_key is None
    assert vctx.plan is ctx.plan
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(9, cfg.d_model)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(cfg.d_model, cfg.d_ff)), jnp.float32)
    np.testing.assert_allclose(np.asarray(vctx.matmul(x, w, name="w_gate")),
                               np.asarray(x @ w), rtol=2e-4, atol=2e-4)
