"""Sampler unit tests: greedy/temperature equivalence, top-k masking,
top-p (nucleus) cutoff properties, and the speculative-decoding greedy
acceptance rule. All seeded, no sampling statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import (SamplerConfig, filter_logits,
                                   greedy_verify, sample)

RNG = jax.random.PRNGKey(3)


def _logits(seed, b=4, v=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v))


# ------------------------------------------------------------------ greedy --

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_greedy_is_argmax(seed):
    """temperature == 0 must reduce to deterministic argmax, independent of
    the rng key and of top-k/top-p settings."""
    logits = _logits(seed)
    for key in (RNG, jax.random.PRNGKey(seed + 100)):
        t = sample(logits, key, SamplerConfig(temperature=0.0, top_k=5,
                                              top_p=0.5))
        assert (t == jnp.argmax(logits, -1)).all()


def test_low_temperature_converges_to_greedy():
    """As T -> 0+, categorical sampling concentrates on the argmax."""
    logits = _logits(7)
    t = sample(logits, RNG, SamplerConfig(temperature=1e-4))
    assert (t == jnp.argmax(logits, -1)).all()


def test_filter_logits_temperature_zero_is_identity():
    """Regression: the default SamplerConfig has temperature 0 (greedy); a
    direct filter_logits call used to divide by it, turning every logit
    into NaN/inf. Scaling must only apply when temperature > 0."""
    logits = _logits(41)
    out = filter_logits(logits, SamplerConfig())
    assert jnp.isfinite(out).all()
    assert (out == logits).all()
    # top-k/top-p still apply at temperature 0
    out = filter_logits(logits, SamplerConfig(top_k=3))
    assert (jnp.isfinite(out).sum(-1) == 3).all()


# ------------------------------------------------------------------- top-k --

@pytest.mark.parametrize("k", [1, 3, 7, 20, 64])
def test_topk_mask_keeps_exactly_topk(k):
    logits = _logits(11)
    out = filter_logits(logits, SamplerConfig(temperature=1.0, top_k=k))
    finite = jnp.isfinite(out)
    assert (finite.sum(-1) == k).all()        # exactly k survivors (no ties
    # in continuous random logits)
    top = jnp.argsort(logits, -1)[:, -k:]
    for b in range(logits.shape[0]):
        assert set(np.where(np.asarray(finite[b]))[0]) == set(np.asarray(top[b]))


@pytest.mark.parametrize("k", [64, 65, 1000])
def test_topk_at_or_above_vocab_keeps_everything(k):
    """Regression: top_k >= V used to index ``sorted[:, -top_k]`` out of
    range; clamped to the vocab it keeps every token (boundary k == V, and
    any k > V)."""
    logits = _logits(43)                     # V = 64
    out = filter_logits(logits, SamplerConfig(temperature=1.0, top_k=k))
    assert jnp.isfinite(out).all()
    assert (out == logits).all()


def test_topk_one_boundary_keeps_only_argmax():
    logits = _logits(47)
    out = filter_logits(logits, SamplerConfig(temperature=1.0, top_k=1))
    finite = jnp.isfinite(out)
    assert (finite.sum(-1) == 1).all()
    assert (jnp.argmax(jnp.where(finite, out, -jnp.inf), -1)
            == jnp.argmax(logits, -1)).all()


def test_topk_one_is_greedy():
    logits = _logits(13)
    t = sample(logits, RNG, SamplerConfig(temperature=1.0, top_k=1))
    assert (t == jnp.argmax(logits, -1)).all()


@pytest.mark.parametrize("k,seed", [(2, 5), (5, 17), (10, 23)])
def test_topk_sampled_token_in_support(k, seed):
    logits = _logits(seed, b=2)
    t = sample(logits, jax.random.PRNGKey(seed + 1),
               SamplerConfig(temperature=1.0, top_k=k))
    top = jnp.argsort(logits, -1)[:, -k:]
    for b in range(2):
        assert int(t[b]) in np.asarray(top[b])


# ------------------------------------------------------------------- top-p --

def _support(logits, p):
    out = filter_logits(logits, SamplerConfig(temperature=1.0, top_p=p))
    return [frozenset(np.where(np.isfinite(np.asarray(out[b])))[0])
            for b in range(logits.shape[0])]


def test_topp_cutoff_monotonic():
    """Nucleus support grows monotonically with p (cutoff monotonicity)."""
    logits = _logits(29)
    supports = [_support(logits, p) for p in (0.1, 0.3, 0.5, 0.7, 0.9, 0.999)]
    for lo, hi in zip(supports, supports[1:]):
        for b in range(logits.shape[0]):
            assert lo[b] <= hi[b]      # subset at every row


def test_topp_support_mass_and_minimality():
    """Kept mass >= p, always includes the argmax, and the nucleus is
    minimal: dropping its least-likely member would fall below p."""
    logits = _logits(31)
    probs = np.asarray(jax.nn.softmax(logits, -1))
    for p in (0.25, 0.5, 0.8):
        for b, sup in enumerate(_support(logits, p)):
            idx = sorted(sup, key=lambda i: probs[b, i])
            mass = probs[b, list(sup)].sum()
            assert mass >= p - 1e-6
            assert int(np.argmax(probs[b])) in sup
            assert mass - probs[b, idx[0]] < p   # minimality


def test_topp_one_keeps_everything():
    logits = _logits(37)
    out = filter_logits(logits, SamplerConfig(temperature=1.0, top_p=1.0))
    assert jnp.isfinite(out).all()


# ----------------------------------------------- speculative verification --

def _target_logits(greedy_tokens, v=32):
    """Logits whose per-position argmax is exactly ``greedy_tokens``."""
    g = np.asarray(greedy_tokens)
    logits = np.full(g.shape + (v,), -1.0, np.float32)
    np.put_along_axis(logits, g[..., None], 5.0, axis=-1)
    return jnp.asarray(logits)


def test_greedy_verify_full_acceptance():
    """Drafts that equal the target's greedy choices all survive, and the
    bonus token (position K) rides along: K+1 emitted."""
    greedy = jnp.asarray([[3, 7, 1, 9]])            # K=3 drafts + bonus
    emitted, n = greedy_verify(greedy[:, :-1], _target_logits(greedy))
    assert int(n[0]) == 4
    assert list(np.asarray(emitted)[0]) == [3, 7, 1, 9]


def test_greedy_verify_zero_acceptance_emits_correction():
    """A hopeless draft still emits exactly the target's own first greedy
    token — speculation can never stall a lane."""
    greedy = jnp.asarray([[3, 7, 1, 9]])
    drafts = jnp.asarray([[4, 7, 1]])               # wrong at position 0
    emitted, n = greedy_verify(drafts, _target_logits(greedy))
    assert int(n[0]) == 1
    assert int(np.asarray(emitted)[0, 0]) == 3


def test_greedy_verify_partial_prefix():
    """Acceptance stops at the FIRST mismatch even if later drafts agree;
    the emitted stream is drafts[:a] + the target's correction at a."""
    greedy = jnp.asarray([[3, 7, 1, 9]])
    drafts = jnp.asarray([[3, 6, 1]])               # mismatch at position 1
    emitted, n = greedy_verify(drafts, _target_logits(greedy))
    assert int(n[0]) == 2
    assert list(np.asarray(emitted)[0, :2]) == [3, 7]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_greedy_verify_matches_sequential_reference(seed):
    """Seeded randomized batch: the vectorized rule equals the obvious
    sequential accept-until-mismatch loop, row by row."""
    rng = np.random.default_rng(seed)
    B, K, V = 5, 4, 16
    drafts = rng.integers(0, V, (B, K)).astype(np.int32)
    logits = rng.normal(size=(B, K + 1, V)).astype(np.float32)
    emitted, n = greedy_verify(jnp.asarray(drafts), jnp.asarray(logits))
    emitted, n = np.asarray(emitted), np.asarray(n)
    greedy = logits.argmax(-1)
    for b in range(B):
        ref = []
        for j in range(K):
            if drafts[b, j] == greedy[b, j]:
                ref.append(drafts[b, j])
            else:
                break
        ref.append(greedy[b, len(ref)])             # correction / bonus
        assert int(n[b]) == len(ref)
        assert list(emitted[b, :len(ref)]) == ref


def test_greedy_verify_is_lossless_vs_greedy_decode():
    """The acceptance rule's emitted prefix is identical to running greedy
    argmax over the same target logits token by token — the invariant that
    makes speculative decoding an execution-schedule change, not a
    sampling change."""
    rng = np.random.default_rng(9)
    logits = rng.normal(size=(3, 5, 8)).astype(np.float32)
    drafts = jnp.asarray(rng.integers(0, 8, (3, 4)), jnp.int32)
    emitted, n = greedy_verify(drafts, jnp.asarray(logits))
    greedy = logits.argmax(-1)
    for b in range(3):
        e = int(np.asarray(n)[b])
        # every emitted token is the target's greedy choice at its position
        assert list(np.asarray(emitted)[b, :e]) == list(greedy[b, :e])
