"""HeteroInfer core invariants: characteristics, profiler, solver, partition
execution, fast sync. Property tests assert the paper's claimed behaviors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.characteristics import (V5E, combine_dual, combine_single,
                                        mxu_matmul_parts, mxu_matmul_time_us,
                                        xla_matmul_parts, xla_matmul_time_us)
from repro.core.partition import HeteroCtx
from repro.core.profiler import (LatencyTable, model_weight_shapes,
                                 profile_analytic)
from repro.core.solver import Decision, PartitionSolver

RNG = jax.random.PRNGKey(0)


# ------------------------------------------------------- characteristics ----

def test_stage_performance_staircase():
    """NPU-1: the MXU compute term is flat within a 128-tile and jumps at
    tile boundaries (total latency = max(compute, memory); the memory term
    is rightly linear in M)."""
    c_64 = mxu_matmul_parts(64, 1024, 1024)[0]
    c_128 = mxu_matmul_parts(128, 1024, 1024)[0]
    c_129 = mxu_matmul_parts(129, 1024, 1024)[0]
    assert c_64 == c_128            # same tile count -> same compute
    assert c_129 > c_128            # next tile -> step up
    # and the full latency still shows the step at compute-bound sizes
    assert mxu_matmul_time_us(129, 8192, 8192) > \
        mxu_matmul_time_us(128, 8192, 8192)


def test_order_sensitivity():
    """NPU-2: [14336,4096]x[4096,K] beats [K,4096]x[4096,14336] (paper
    Fig 4) — a COMPUTE-term property (pipeline-refill amortization over M);
    at these sizes total latency can be memory-bound on both orders, where
    the distinction rightly vanishes."""
    K = 64
    fast = mxu_matmul_parts(14336, 4096, K)[0]    # big M, small weight
    slow = mxu_matmul_parts(K, 4096, 14336)[0]    # small M, huge weight
    assert fast < slow / 1.5
    # equal FLOPs!
    assert 2 * 14336 * 4096 * K == 2 * K * 4096 * 14336


def test_shape_sensitivity():
    """NPU-3: row-heavy activations beat column-heavy at equal FLOPs."""
    assert mxu_matmul_parts(4096, 1024, 256)[0] < \
        mxu_matmul_parts(256, 1024, 4096)[0]


def test_xla_linear_performance():
    """GPU-1: XLA-path latency grows ~linearly in M (no staircase)."""
    ts = [xla_matmul_time_us(m, 2048, 2048) for m in (256, 512, 1024, 2048)]
    ratios = [ts[i + 1] / ts[i] for i in range(3)]
    for r in ratios:
        assert 1.5 < r < 2.5        # ~2x per doubling once compute-bound


def test_dual_stream_bandwidth_aggregation():
    """Memory-1: concurrent paths beat either path alone on memory-bound ops."""
    a = mxu_matmul_parts(1, 4096, 2048)
    b = xla_matmul_parts(1, 4096, 2048)
    dual = combine_dual(a, b)
    assert dual < combine_single((a[0] + b[0], a[1] + b[1]))


# ------------------------------------------------------------------ solver --

@pytest.fixture(scope="module")
def llama_solver():
    cfg = get_config("llama3-8b")
    return PartitionSolver(profile_analytic(cfg), sync_mode="fast"), cfg


def test_solver_beats_single_paths(llama_solver):
    """T_total <= min(T_xla_all, T_mxu_all) + sync for every site/M."""
    solver, cfg = llama_solver
    for site in ("wq", "w_up", "w_down", "head"):
        for M in (1, 64, 256, 300, 4096):
            d = solver.solve_site(site, M)
            t_xla = solver.table.lookup(site, M, "xla")
            assert d.t_us <= t_xla + 1e-6, (site, M, d)


def test_solver_decode_uses_partition(llama_solver):
    """Decode (M=1) is memory-bound -> dual-engine weight split wins
    (paper Table 3 row 1)."""
    solver, _ = llama_solver
    d = solver.solve_site("wq", 1)
    assert d.strategy == "weight"
    # flexible path takes the majority (paper: GPU does most of decode)
    assert d.n_split <= (4096 - d.n_split)


def test_solver_host_sync_kills_partitioning():
    """With 400us-class sync, small-op partitioning loses (paper's GPU-2)."""
    cfg = get_config("llama3-8b")
    s_host = PartitionSolver(profile_analytic(cfg), sync_mode="host")
    d = s_host.solve_site("wq", 1)
    assert d.strategy == "xla_only"


def test_solver_alignment_decisions(llama_solver):
    """128-aligned splits only (the MXU static-shape constraint)."""
    solver, _ = llama_solver
    for M in (128, 256, 300, 1024):
        d = solver.solve_site("w_down", M)
        assert d.n_split % 128 == 0
        if d.strategy in ("act", "hybrid"):
            assert d.m_bucket % 128 == 0


@pytest.mark.parametrize("M", [1, 2, 64, 127, 128, 129, 300, 1000, 4096])
def test_solver_total_never_worse_than_xla(M):
    cfg = get_config("qwen3-1.7b")
    solver = PartitionSolver(profile_analytic(cfg), sync_mode="fast")
    d = solver.solve_site("w_gate", M)
    assert d.t_us <= solver.table.lookup("w_gate", M, "xla") + 1e-6


def test_kv_mode_choice():
    """Archs whose kv-heads divide the model axis keep head sharding; others
    flip to split-KV sequence sharding."""
    s = PartitionSolver(profile_analytic(get_config("qwen2-moe-a2.7b")))
    assert s.solve_kv_mode(get_config("qwen2-moe-a2.7b")) == "head"  # 16 % 16
    s2 = PartitionSolver(profile_analytic(get_config("tinyllama-1.1b")))
    assert s2.solve_kv_mode(get_config("tinyllama-1.1b")) == "seq"   # 4 kv heads


# ------------------------------------------------- partition execution ------

@pytest.mark.parametrize("strategy,kw", [
    ("xla_only", {}),
    ("mxu_only", {}),
    ("pad", {"m_bucket": 384}),
    ("weight", {"n_split": 128}),
    ("act", {"m_bucket": 256}),
    ("hybrid", {"m_bucket": 256, "n_split": 128}),
])
def test_partition_strategies_are_exact(strategy, kw):
    """Every strategy computes the SAME matmul (partitioning is an execution
    detail, never a numerics change)."""
    M, K, N = 300, 256, 384
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (2, 150, K), jnp.float32)   # leading dims fold
    w = jax.random.normal(k2, (K, N), jnp.float32)
    ctx = HeteroCtx(mode="hetero-tensor", plan=None)
    dec = Decision(site="t", M=M, strategy=strategy, t_us=0.0, **kw)
    y = ctx.execute(dec, x.reshape(M, K), w)
    ref = x.reshape(M, K) @ w
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


HETERO_CTX_CASES = [(2, 1, 1, "xla"), (127, 2, 1, "mxu"),
                    (128, 1, 3, "hetero-layer"), (300, 3, 2, "xla"),
                    (65, 2, 2, "mxu"), (256, 1, 1, "hetero-layer")]


@pytest.mark.parametrize("M,nk,nn,mode", HETERO_CTX_CASES)
def test_hetero_ctx_modes_exact(M, nk, nn, mode):
    K, N = nk * 128, nn * 128
    k1, k2 = jax.random.split(RNG)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    y = HeteroCtx(mode=mode).matmul(x, w, name="wq")
    assert float(jnp.max(jnp.abs(y - x @ w))) < 1e-4


# -------------------------------------------------------------- fast sync --

def test_on_device_loop_matches_host_loop():
    from repro.core.sync import generate_host_loop, generate_on_device
    from repro.models import build_model
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size)
    c1 = model.init_cache(batch=2, max_len=40, dtype=jnp.float32)
    _, c1 = model.prefill(params, toks, c1)
    c2 = jax.tree.map(jnp.copy, c1)
    first = jnp.zeros((2, 1), jnp.int32)
    t1, _ = generate_on_device(model, params, first, c1, 8)
    t2, _ = generate_host_loop(model, params, first, c2, 8)
    assert (jnp.asarray(t1) == jnp.asarray(t2)).all()
