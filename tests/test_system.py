"""End-to-end system behaviors tying the paper's pipeline together:
profiler -> solver -> plan -> engine, plus roofline/dry-run plumbing."""
import json

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, get_smoke_config
from repro.configs.base import cell_is_supported
from repro.core.engine import InferenceEngine
from repro.core.profiler import (LatencyTable, model_weight_shapes,
                                 profile_analytic)
from repro.core.solver import PartitionPlan, PartitionSolver


def test_all_archs_have_exact_assigned_configs():
    expect = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch


def test_cell_grid_covers_40():
    cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    assert len(cells) == 40
    skips = [(a, s) for a, s in cells
             if not cell_is_supported(get_config(a), SHAPES[s])[0]]
    # 8 full-attention archs skip long_500k; hubert skips both decode shapes
    assert len(skips) == 8 + 1 + 1 - 1  # hubert long_500k counted once
    runnable = len(cells) - len(skips)
    assert runnable == 31


def test_profiler_solver_plan_roundtrip(tmp_path):
    cfg = get_config("qwen3-1.7b")
    table = profile_analytic(cfg)
    table.save(tmp_path / "table.json")
    table2 = LatencyTable.load(tmp_path / "table.json")
    assert table2.lookup("wq", 256, "mxu") == table.lookup("wq", 256, "mxu")

    plan = PartitionSolver(table2).solve(cfg, Ms=(1, 256))
    plan.save(tmp_path / "plan.json")
    plan2 = PartitionPlan.load(tmp_path / "plan.json")
    assert plan2.decision("wq", 256) == plan.decision("wq", 256)


def test_profiler_covers_all_model_sites():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        sites = model_weight_shapes(cfg)
        assert len(sites) >= 5, arch
        for s, (K, N) in sites.items():
            assert K > 0 and N > 0


def test_engine_ablation_ordering():
    """Analytic engine prediction: hetero-tensor <= xla-only prefill latency
    (the paper's headline claim, directionally)."""
    cfg = get_config("llama3-8b")
    table = profile_analytic(cfg)
    xla_t = sum(table.lookup(s, 320, "xla") for s in table.sites
                if s != "head")
    solver = PartitionSolver(table, sync_mode="fast")
    het_t = sum(solver.solve_site(s, 320).t_us for s in table.sites
                if s != "head")
    assert het_t < xla_t


def _scan_dryrun_artifacts(art, cells):
    """Validation shared by the committed-artifact and hermetic paths:
    every cell must have a record and every record must be ok."""
    bad = []
    for arch, shape, mesh in cells:
        p = art / f"{arch}__{shape}__{mesh}.json"
        if not p.exists():
            bad.append((arch, shape, mesh, "missing"))
            continue
        rec = json.loads(p.read_text())
        if not rec.get("ok"):
            bad.append((arch, shape, mesh, rec.get("error", "?")[:80]))
    return bad


def test_dryrun_artifacts_pass(tmp_path):
    """Dry-run artifacts must show every covered cell OK on both meshes.

    With a committed artifact set (`artifacts/dryrun`) the full
    arch x shape x mesh grid is validated. Without one the test is
    HERMETIC instead of skipping: it generates a reduced artifact set into
    ``tmp_path`` through the real ``run_cell`` entry point — unsupported
    cells, which exercise the config -> support-gate -> record -> save
    pipeline end-to-end without a production-mesh compile — and validates
    those with the same scanner."""
    from pathlib import Path
    from repro.launch.dryrun import run_cell

    art = Path("artifacts/dryrun")
    meshes = ("pod16x16", "pod2x16x16")
    if art.exists():
        cells = [(a, s, m) for a in ASSIGNED_ARCHS for s in SHAPES
                 for m in meshes]
    else:
        art = tmp_path
        gen = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES
               if not cell_is_supported(get_config(a), SHAPES[s])[0]]
        assert gen, "support grid unexpectedly has no unsupported cells"
        cells = []
        for a, s in gen:
            for multipod, mesh in ((False, meshes[0]), (True, meshes[1])):
                rec = run_cell(a, s, multi_pod=multipod, out_dir=art)
                assert rec["skipped"] and rec["ok"], (a, s, mesh)
                cells.append((a, s, mesh))
    assert not _scan_dryrun_artifacts(art, cells)


def test_dryrun_scanner_flags_failures(tmp_path):
    """The artifact scanner must catch both failure modes: a missing cell
    record and a recorded failure (ok=False)."""
    (tmp_path / "a__s__m.json").write_text(json.dumps(
        {"ok": False, "error": "OOM: requested 2TiB"}))
    bad = _scan_dryrun_artifacts(tmp_path, [("a", "s", "m"), ("b", "s", "m")])
    assert ("a", "s", "m", "OOM: requested 2TiB") in bad
    assert ("b", "s", "m", "missing") in bad
