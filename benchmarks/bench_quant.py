"""Quantized serving: capacity, parity, re-planning, and accuracy drift.

Four arms, one per claim the quantized path makes:

  * ``quant.capacity_*`` — EQUAL pool bytes, fp(bf16) vs int8 KV: the int8
    pool (1-byte codes + per-slot bf16 scales) holds ~1.88x the token
    blocks, so a flood of short requests sustains >= 1.8x the peak
    concurrent sequences — the serving-capacity lever on a capacity-bound
    unified-memory SoC.
  * ``quant.serve_*`` — W4A16 weights + int8 KV through the host-synced,
    fused-window, and mixed-batch schedulers: greedy outputs must be
    token-identical to the sequential quantized reference (same codes
    dequantized everywhere), with tok/s reported per arm.
  * ``quant.plan_*`` — the solver re-plans under quantized weight-stream
    bytes: fp vs int8 vs W4A16 plans on the REAL llama3-8b config must
    differ on at least one decode shape (the re-planned split is recorded
    in BENCH_quant.json).
  * ``quant.nll_*`` — the perplexity-drift mini-eval of
    tests/test_quant_quality.py, reported as a number next to the speed
    claims: fp vs int8 vs W4A16 NLL on real smollm-135m.

Rows land in ``BENCH_quant.json`` (benchmarks/run.py folds the metrics
into BENCH_summary.json).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config, get_smoke_config
from repro.core.engine import build_plan
from repro.models import build_model
from repro.models.quant import quantize_params, score_nll
from repro.serving.scheduler import PagedBatcher, Request

BS = 16                 # block size for both capacity arms
NB_INT8 = 64            # int8 pool blocks; the fp arm gets the SAME bytes


def _pool_blocks_at_equal_bytes(cfg) -> int:
    """fp-bf16 blocks purchasable with NB_INT8 int8 blocks' bytes."""
    slot = cfg.n_kv_heads * cfg.head_dim
    int8_block = 2 * cfg.n_layers * (BS * slot * 1 + BS * 2)  # codes+scales
    fp_block = 2 * cfg.n_layers * BS * slot * 2
    return NB_INT8 * int8_block // fp_block


def _capacity_arm(cfg, params, kv_quant, num_blocks):
    """Flood of 1-block requests; returns (batcher, elapsed_s, tokens)."""
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                        np.int32),
                    max_new_tokens=4)
            for i in range(80)]
    cb = PagedBatcher(cfg, params, num_blocks=num_blocks, block_size=BS,
                      max_blocks_per_seq=1, decode_width=70,
                      buckets=(32, 64), sync="device", window=4,
                      kv_quant=kv_quant)
    t0 = time.perf_counter()
    cb.run(reqs)
    dt = time.perf_counter() - t0
    cb.kv.assert_drained()
    return cb, dt, sum(len(r.output) for r in reqs)


def _paged_reference(model, params, prompt, n, kv_quant, max_len=96):
    """Sequential single-request quantized oracle (paged path)."""
    nbs = -(-max_len // BS)
    pool = model.init_paged_cache(num_blocks=nbs + 1, block_size=BS,
                                  dtype=jnp.float32, kv_quant=kv_quant)
    bt = jnp.arange(1, nbs + 1, dtype=jnp.int32)[None]
    logits, pool = model.paged_prefill(params, jnp.asarray(prompt)[None],
                                       pool, block_table=bt, start_index=0)
    out = [int(jnp.argmax(logits[0, -1]))]
    length = len(prompt)
    for _ in range(n - 1):
        logits, pool = model.paged_decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), pool,
            block_tables=bt, lengths=jnp.asarray([length]))
        out.append(int(jnp.argmax(logits[0, -1])))
        length += 1
    return out


def main() -> None:
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    metrics: dict = {}

    # ---- capacity at equal pool bytes: bf16 KV vs int8 KV ----------------
    nb_fp = _pool_blocks_at_equal_bytes(cfg)
    arms = {}
    for kv, nb in ((None, nb_fp), ("int8", NB_INT8)):
        cb, dt, tok = _capacity_arm(cfg, params, kv, nb)
        name = kv or "bf16"
        arms[name] = cb
        emit(f"quant.capacity_{name}", dt * 1e6,
             f"blocks={nb};pool_bytes={cb.kv.pool_bytes()};"
             f"peak={cb.peak_active};tok_s={tok / dt:.1f}")
    assert arms["bf16"].kv.pool_bytes() == arms["int8"].kv.pool_bytes(), \
        "capacity arms must compare at equal pool bytes"
    ratio = arms["int8"].peak_active / arms["bf16"].peak_active
    assert ratio >= 1.8, (
        f"int8 KV peak concurrency {arms['int8'].peak_active} vs bf16 "
        f"{arms['bf16'].peak_active}: ratio {ratio:.2f} < 1.8 at equal "
        "pool memory")
    metrics.update(peak_bf16=arms["bf16"].peak_active,
                   peak_int8=arms["int8"].peak_active,
                   capacity_ratio=round(ratio, 2),
                   pool_bytes=arms["int8"].kv.pool_bytes())

    # ---- quantized token identity across scheduler arms ------------------
    fcfg = cfg.with_(param_dtype="float32", compute_dtype="float32")
    fmodel = build_model(fcfg)
    fparams = fmodel.init(jax.random.PRNGKey(7))
    qparams = quantize_params(fparams, fcfg, "w4a16")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, fcfg.vocab_size, s).astype(np.int32)
               for s in (9, 33, 20, 48, 57)]
    refs = [_paged_reference(fmodel, qparams, p, 6, "int8")
            for p in prompts]
    match = True
    for arm, kw in (("host", dict(sync="host")),
                    ("device", dict(sync="device", window=3)),
                    ("mixed", dict(sync="device", window=3,
                                   mixed_batch=True))):
        cb = PagedBatcher(fcfg, fparams, num_blocks=40, block_size=BS,
                          max_blocks_per_seq=5, decode_width=3,
                          buckets=(32, 64), cache_dtype=jnp.float32,
                          weight_quant="w4a16", kv_quant="int8", **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        cb.run(reqs)
        dt = time.perf_counter() - t0
        cb.kv.assert_drained()
        ok = all(r.output == refs[r.rid] for r in reqs)
        match &= ok
        tok = sum(len(r.output) for r in reqs)
        emit(f"quant.serve_{arm}", dt * 1e6,
             f"w=w4a16;kv=int8;tok_s={tok / dt:.1f};match={ok}")
    assert match, "quantized greedy outputs diverged from the sequential " \
                  "quantized reference"
    metrics["token_identical"] = match

    # ---- solver re-planning under quantized weight bytes -----------------
    real = get_config("llama3-8b")
    _, fp_plan = build_plan(real)
    for fmt in ("int8", "w4a16"):
        _, qplan = build_plan(real, weight_quant=fmt)
        diffs = sorted(k for k, d in qplan.decisions.items()
                       if fp_plan.decisions[k].describe()
                       != d.describe())
        assert diffs, f"{fmt}: solver plan identical to fp on every shape"
        site, m = diffs[0]
        metrics[f"plan_diffs_{fmt}"] = len(diffs)
        metrics[f"replan_{fmt}"] = (
            f"{site}@M={m}: {fp_plan.decisions[(site, m)].describe()}"
            f" -> {qplan.decisions[(site, m)].describe()}")
        emit(f"quant.plan_{fmt}", 0.0,
             f"diffs={len(diffs)};example={site}@M={m}")

    # ---- accuracy drift (the quality gate's metric, as a number) ---------
    scfg = get_config("smollm-135m").with_(param_dtype="float32",
                                           compute_dtype="float32")
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(11))
    tokens = jax.random.randint(jax.random.PRNGKey(12), (2, 129),
                                0, scfg.vocab_size)
    base = score_nll(smodel, sparams, tokens)
    metrics["nll_fp"] = round(base, 4)
    emit("quant.nll_fp", 0.0, f"nll={base:.4f}")
    for fmt in ("int8", "w4a16"):
        q = score_nll(smodel, quantize_params(sparams, scfg, fmt), tokens)
        metrics[f"nll_drift_{fmt}"] = round(abs(q - base), 4)
        emit(f"quant.nll_{fmt}", 0.0,
             f"nll={q:.4f};drift={abs(q - base):.4f}")

    emit_json("quant", metrics)


if __name__ == "__main__":
    main()
