"""Paper Fig 13 — prefill speed across engine modes and sequence lengths.

Arms: xla-only (= MNN/MLC GPU-only), hetero-layer, hetero-tensor. Both the
solver's analytic TPU-v5e latency (the deploy prediction the paper's tables
correspond to) and measured CPU wall-clock of the real engine (mechanism
check) are reported.
"""
from __future__ import annotations

import jax

from repro.configs import get_config, get_smoke_config
from repro.core.engine import InferenceEngine
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver

from .common import emit, emit_json

SEQ_LENS = (64, 256, 1024)


def analytic_arm(arch: str):
    cfg = get_config(arch)
    table = profile_analytic(cfg)
    solver = PartitionSolver(table, sync_mode="fast")
    for S in SEQ_LENS:
        t_xla = sum(table.lookup(s, S, "xla") for s in table.sites
                    if s != "head") * cfg.n_layers
        t_mxu = sum(table.lookup(s, S, "mxu") for s in table.sites
                    if s != "head") * cfg.n_layers
        t_het = sum(solver.solve_site(s, S).t_us for s in table.sites
                    if s != "head") * cfg.n_layers
        emit(f"fig13_prefill_model/{arch}/S={S}/xla", t_xla,
             f"tok_s={S/t_xla*1e6:.0f}")
        emit(f"fig13_prefill_model/{arch}/S={S}/mxu", t_mxu,
             f"tok_s={S/t_mxu*1e6:.0f}")
        emit(f"fig13_prefill_model/{arch}/S={S}/hetero", t_het,
             f"tok_s={S/t_het*1e6:.0f},speedup_vs_xla={t_xla/t_het:.2f}x")


def measured_arm():
    cfg = get_smoke_config("llama3-8b")
    prompt = jax.random.randint(jax.random.PRNGKey(0), (1, 256), 0,
                                cfg.vocab_size)
    for mode in ("xla", "hetero-layer", "hetero-tensor"):
        eng = InferenceEngine(cfg, mode=mode, max_len=512)
        eng.generate(prompt, max_new_tokens=2)   # warm
        eng.stats.prefill_s = eng.stats.prefill_tokens = 0
        eng.generate(prompt, max_new_tokens=2)
        tps = eng.stats.tokens_per_s()["prefill_tok_s"]
        emit(f"fig13_prefill_measured/smoke/{mode}",
             eng.stats.prefill_s * 1e6, f"tok_s={tps:.0f}")


def main() -> None:
    for arch in ("llama3-8b", "internlm-1.8b", "tinyllama-1.1b"):
        analytic_arm(arch)
    measured_arm()

    emit_json("prefill")


if __name__ == "__main__":
    main()
