"""Stage-parallel mixed batching vs admit-then-decode (serving tentpole,
paper §4.1-§4.3 applied at the stage level).

The paged batcher's baseline arms run admission prefill chunks as their own
dispatches, then decode separately — the two workload shapes the partition
solver was built to co-schedule never overlap. The mixed-batch arm
(``PagedBatcher(mixed_batch=True)``) fuses one bucket-sized prefill chunk
per scheduler step into the decode dispatch of the running lanes
(``transformer.mixed_step`` / the chunk-carrying ``paged_decode_window``),
so admission rides along for free and decode never stalls while a request
is admitted.

The workload staggers arrivals (a fresh request is submitted every few
ticks while earlier ones decode), the regime mixed batching targets. For
each sync arm ('host' per-token loop, 'device' fused windows) the bench
asserts:
  * bit-exact greedy outputs across all arms (fusion is an execution
    schedule change, never a numerics change), and
  * the mixed arm issues STRICTLY fewer host dispatches per finished token
    than admit-then-decode at the same workload, with fused_steps > 0
    (chunks actually rode decode dispatches).

It also prints the solver's analytic account of the same fusion: the MIXED
strategy latency (`solve_mixed`, concurrent pair on the Memory-1
dual-stream pool) vs serializing the two stages.

Rows: ``mixed_batch.<sync>.<arm>,us_total,...`` +
``mixed_batch.solver.<site>`` analytic rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request

BLOCK_SIZE = 16
NEW_TOKENS = 25                       # 24 decode steps per request
PROMPT_SIZES = (56, 40, 70, 33, 62, 45)
ARRIVAL_GAP = 3                       # ticks between request arrivals
WINDOW = 4


def _requests(cfg) -> list[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=NEW_TOKENS)
            for i, s in enumerate(PROMPT_SIZES)]


def _run_staggered(cfg, params, **kw) -> tuple[list[Request], float,
                                               PagedBatcher]:
    """Drive the batcher tick-by-tick, submitting one request every
    ``ARRIVAL_GAP`` ticks — decode is always in flight when later requests
    admit, which is exactly when admission dispatches can fuse."""
    max_len = max(PROMPT_SIZES) + NEW_TOKENS
    n = len(PROMPT_SIZES)
    pb = PagedBatcher(cfg, params,
                      num_blocks=1 + n * -(-max_len // BLOCK_SIZE),
                      block_size=BLOCK_SIZE,
                      max_blocks_per_seq=-(-max_len // BLOCK_SIZE),
                      decode_width=n, buckets=(32, 64),
                      cache_dtype=jnp.float32, **kw)
    reqs = _requests(cfg)
    t0 = time.perf_counter()
    tick = 0
    pending = list(reqs)
    while pending or pb.busy:
        if pending and tick % ARRIVAL_GAP == 0:
            pb.submit(pending.pop(0))
        pb.step()
        tick += 1
        assert tick < 10_000
    pb.kv.assert_drained()
    return reqs, time.perf_counter() - t0, pb


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))

    for sync in ("host", "device"):
        kw = {"sync": sync} if sync == "host" else \
             {"sync": sync, "window": WINDOW}
        reqs_b, dt_b, base = _run_staggered(cfg, params, **kw)
        bs = base.stats()
        tokens = sum(len(r.output) for r in reqs_b)
        emit(f"mixed_batch.{sync}.admit_then_decode", dt_b * 1e6,
             f"dispatches={bs['total_dispatches']};tokens={tokens};"
             f"disp_per_tok={bs['total_dispatches'] / tokens:.3f}")
        reqs_m, dt_m, mixed = _run_staggered(cfg, params, mixed_batch=True,
                                             **kw)
        ms = mixed.stats()
        match = all(b.output == m.output for b, m in zip(reqs_b, reqs_m))
        emit(f"mixed_batch.{sync}.mixed", dt_m * 1e6,
             f"dispatches={ms['total_dispatches']};tokens={tokens};"
             f"disp_per_tok={ms['total_dispatches'] / tokens:.3f};"
             f"fused_chunks={ms['fused_steps']};"
             f"standalone_prefill={ms['prefill_dispatches']};match={match}")
        assert match, (f"sync={sync}: mixed-batch greedy outputs diverged "
                       "from admit-then-decode")
        assert ms["fused_steps"] > 0, \
            f"sync={sync}: no prefill chunk ever fused into a decode dispatch"
        assert ms["total_dispatches"] < bs["total_dispatches"], (
            f"sync={sync}: mixed arm issued {ms['total_dispatches']} "
            f"dispatches vs {bs['total_dispatches']} for admit-then-decode; "
            "expected strictly fewer per finished token")

    # the solver's analytic account of the same fusion (full-size model):
    # MIXED pairs a bucket-sized prefill chunk (MXU path) with a
    # decode-width micro-batch (flexible path) on the dual-stream pool
    from repro.configs import get_config
    full = get_config("llama3-8b")
    solver = PartitionSolver(profile_analytic(full), sync_mode="fast")
    for site in ("wq", "w_gate", "head"):
        dec = solver.solve_mixed(site, 256, 8)
        gain = solver.mixed_gain_us(site, 256, 8)
        emit(f"mixed_batch.solver.{site}", dec.t_us,
             f"strategy={dec.strategy};ratio={dec.ratio};"
             f"gain_vs_serial_us={gain:.1f}")

    emit_json("mixed_batch")


if __name__ == "__main__":
    main()
