"""Paper Figs 1/3/4 — the three processor characteristics.

Two layers of evidence per characteristic:
  * analytic: the TPU-v5e cost model (deploy target) — the staircase /
    order / linearity structure the solver exploits;
  * measured: wall-clock of the two real executable paths on this backend
    (XLA matmul vs the Pallas MXU-path kernel in interpret mode). CPU wall
    times are NOT TPU times; what must (and does) reproduce is the SHAPE of
    each curve, which is what the solver consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.characteristics import mxu_matmul_time_us, xla_matmul_time_us

from .common import bench, emit, emit_json


def main() -> None:
    # --- Fig 1: XLA-path linear performance (model)
    for m in (64, 128, 256, 512, 1024, 2048, 4096):
        t = xla_matmul_time_us(m, 2048, 2048)
        emit(f"fig1_xla_linear/M={m}", t,
             f"tflops={2*m*2048*2048/t/1e6:.2f}")

    # --- Fig 3: MXU stage performance (model): staircase across a tile edge
    for m in (96, 112, 120, 128, 136, 160, 192, 224, 256, 288):
        t = mxu_matmul_time_us(m, 4096, 4096)
        emit(f"fig3_mxu_stage/M={m}", t, f"tile={-(-m//128)}")

    # --- Fig 4: order sensitivity at equal FLOPs (model)
    for k in (32, 64, 128):
        fwd = mxu_matmul_time_us(14336, 4096, k)
        rev = mxu_matmul_time_us(k, 4096, 14336)
        emit(f"fig4_order/K={k}_rowmajor", fwd, f"speedup={rev/fwd:.2f}x")
        emit(f"fig4_order/K={k}_colmajor", rev, "")

    # --- measured counterparts (structure check on this backend)
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (1024, 1024), jnp.float32)
    xla_mm = jax.jit(lambda a, b: a @ b)
    for m in (64, 128, 256, 512, 1024):
        x = jax.random.normal(rng, (m, 1024), jnp.float32)
        emit(f"fig1_xla_measured/M={m}", bench(xla_mm, x, w), "cpu-backend")

    emit_json("characteristics")


if __name__ == "__main__":
    main()
