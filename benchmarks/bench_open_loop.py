"""Open-loop serving latency: TTFT / TPOT / queue-delay percentiles under
seeded arrival streams, across every serving arm.

Everything before this benchmark measured dispatch counts in a closed
loop; this one measures what a USER sees. A deterministic virtual-time
harness (``FakeClock`` + a fixed per-tick cost) drives the async ingress
(serving/ingress.py) over seeded Poisson and bursty arrivals, so the
latency percentiles are bitwise-reproducible across runs — the same
numbers CI would get, with zero real sleeps.

Arms (all greedy, all token-identical to the sequential reference, all
draining the paged pool):

  * ``closed``       — every request at t=0 (the old regime, for contrast);
  * ``host``         — open-loop Poisson over per-token host-synced decode;
  * ``host_burst``   — the same arm under bursty on-off arrivals (same
                       long-run rate; the tail is the story);
  * ``device``       — fused-window decode (fewer host syncs per token);
  * ``mixed``        — stage-parallel prefill⊕decode fusion;
  * ``spec``         — speculative decoding (k=2 self-draft);
  * ``prefix``       — shared-system-prompt traffic with the prefix cache;
  * ``bp_preempt``   — a deliberately undersized pool with a priority mix:
                       watermark backpressure defers admissions and blocked
                       high-priority arrivals PREEMPT low-priority lanes
                       (KV retires through the prefix cache; resumes
                       re-prefill only the uncached suffix). Asserts
                       preemptions actually happened AND outputs stayed
                       token-identical.

Also asserts the ``host`` arm's full report is bitwise-identical when
re-run — the determinism contract the tier-1 harness pins.

Rows: ``open_loop.<arm>.<metric>`` (us). ``BENCH_open_loop.json`` carries
the full percentile reports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.ingress import (AsyncServer, burst_arrivals,
                                   open_loop_workload, poisson_arrivals)
from repro.serving.scheduler import PagedBatcher
from repro.serving.spec import SpecConfig
from repro.serving.telemetry import FakeClock
from repro.serving.sampler import SamplerConfig

BS = 16                    # pool block size
N_REQ = 6
RATE = 150.0               # req/s of virtual time (1 tick = 1 ms)
STEP_TIME_S = 1e-3
SLO_MS = 120.0
SYS_PROMPT_LEN = 32        # two full shared blocks (prefix arm)
TAIL_LENS = (7, 20, 0, 13, 33, 16)
BUDGETS = (6, 5, 7, 4, 6, 5)


def _reference(model, params, prompt, n):
    cache = model.init_cache(batch=1, max_len=256, dtype=jnp.float32)
    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def _prompts(cfg, shared: bool):
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size,
                              SYS_PROMPT_LEN).astype(np.int32)
    out = []
    for t in TAIL_LENS:
        tail = rng.integers(0, cfg.vocab_size, t).astype(np.int32)
        out.append(np.concatenate([sys_prompt, tail]) if shared
                   else np.concatenate([
                       rng.integers(0, cfg.vocab_size,
                                    SYS_PROMPT_LEN).astype(np.int32), tail]))
    return out


def _run_arm(cfg, params, refs, prompts, times, *, priorities=None,
             num_blocks=None, watermark=0, **batcher_kw):
    max_len = SYS_PROMPT_LEN + max(TAIL_LENS) + max(BUDGETS) + 1
    nb = num_blocks or (1 + N_REQ * -(-max_len // BS))
    pb = PagedBatcher(cfg, params, num_blocks=nb, block_size=BS,
                      max_blocks_per_seq=-(-max_len // BS), decode_width=3,
                      buckets=(32, 64), cache_dtype=jnp.float32,
                      sampler=SamplerConfig(), **batcher_kw)
    server = AsyncServer(pb, clock=FakeClock(), step_time_s=STEP_TIME_S,
                         admit_watermark=watermark)
    handles = server.run_sync(open_loop_workload(
        prompts, BUDGETS, times, priorities))
    for h, ref in zip(handles, refs):
        assert h.done and h.terminal_events == 1, h.rid
        assert h.tokens == ref, (
            f"rid {h.rid}: open-loop output diverged from the sequential "
            f"reference ({h.tokens} vs {ref})")
    pb.kv.assert_drained()
    return server


def _record(arm: str, server: AsyncServer, metrics: dict) -> None:
    rep = server.report(slo_ms=SLO_MS)
    st = server.stats()
    for m in ("ttft_ms", "tpot_ms", "queue_delay_ms"):
        for q in ("p50", "p95", "p99"):
            emit(f"open_loop.{arm}.{m.removesuffix('_ms')}_{q}",
                 rep[m][q] * 1e3)       # ms -> us rows
    emit(f"open_loop.{arm}.goodput", rep["goodput_req_s"] * 1e6,
         f"attainment={rep['slo_attainment']:.2f};"
         f"preempt={st['preemptions']};defer={st['ingress_deferrals']};"
         f"ticks={st['ingress_ticks']}")
    metrics[arm] = {
        "ttft_ms": rep["ttft_ms"], "tpot_ms": rep["tpot_ms"],
        "queue_delay_ms": rep["queue_delay_ms"],
        "goodput_req_s": rep["goodput_req_s"],
        "slo_attainment": rep["slo_attainment"],
        "makespan_s": rep["makespan_s"],
        "preemptions": st["preemptions"],
        "deferrals": st["ingress_deferrals"],
    }


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    prompts = _prompts(cfg, shared=False)
    shared_prompts = _prompts(cfg, shared=True)
    refs = [_reference(model, params, p, m)
            for p, m in zip(prompts, BUDGETS)]
    shared_refs = [_reference(model, params, p, m)
                   for p, m in zip(shared_prompts, BUDGETS)]

    poisson = poisson_arrivals(RATE, N_REQ, seed=0)
    burst = burst_arrivals(RATE, N_REQ, seed=0)
    closed = np.zeros(N_REQ)
    metrics: dict = {}

    _record("closed", _run_arm(cfg, params, refs, prompts, closed), metrics)
    host = _run_arm(cfg, params, refs, prompts, poisson)
    _record("host", host, metrics)
    _record("host_burst", _run_arm(cfg, params, refs, prompts, burst),
            metrics)
    _record("device", _run_arm(cfg, params, refs, prompts, poisson,
                               sync="device", window=3), metrics)
    _record("mixed", _run_arm(cfg, params, refs, prompts, poisson,
                              sync="device", window=3, mixed_batch=True),
            metrics)
    _record("spec", _run_arm(cfg, params, refs, prompts, poisson,
                             spec=SpecConfig(k=2)), metrics)
    _record("prefix", _run_arm(cfg, params, shared_refs, shared_prompts,
                               poisson, prefix_cache=True), metrics)

    # backpressure + preemption: pool sized so the first two low-priority
    # admissions leave no headroom for the high-priority arrivals (which
    # land in one tight burst right behind them) — the watermark defers
    # them and they preempt; prefix cache makes the resumes suffix-only
    prios = [0, 0, 1, 1, 0, 1]
    bp = _run_arm(cfg, params, shared_refs, shared_prompts,
                  burst_arrivals(600.0, N_REQ, seed=2, burst_size=N_REQ),
                  priorities=prios, num_blocks=9, watermark=1,
                  prefix_cache=True)
    _record("bp_preempt", bp, metrics)
    assert bp.preemptions > 0, "backpressure arm exercised no preemption"
    assert bp.deferrals > 0, "backpressure arm exercised no deferral"
    assert bp.stats()["prefix_hits"] > 0, "resumes never hit the cache"

    # determinism: same seeds, same clock, same bits — the whole harness's
    # reason to exist as a *measuring* instrument
    rerun = _run_arm(cfg, params, refs, prompts, poisson)
    assert rerun.report(slo_ms=SLO_MS) == host.report(slo_ms=SLO_MS), (
        "open-loop report is not bitwise-reproducible across identical runs")
    metrics["bitwise_reproducible"] = True

    emit_json("open_loop", metrics)


if __name__ == "__main__":
    main()
