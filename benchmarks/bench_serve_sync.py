"""Host-synced vs fused-window paged decode (serving tentpole, §4.3 at
serving batch widths).

The paged batcher's host-synced arm pays one dispatch + host round-trip per
decoded token — the serving-scale analogue of the paper's ~400us-clFinish-
per-kernel problem (GPU-2). The fused-window arm (`--sync device`) runs a
whole window of decode steps as ONE jitted `lax.scan` dispatch, so per-
request host dispatches drop by ~the window width, with greedy outputs
token-exact across both arms (fast sync is a schedule change, never a
numerics change).

Sweeps batch width x window width and asserts, for each configuration:
  * both arms emit identical greedy token streams, and
  * the host arm issues >= window more decode dispatches than the fused
    arm (the acceptance property: one round-trip per window, not per token).

Rows: ``serve_sync.B<batch>.<arm>[.w<window>],us_total,...``
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request

BLOCK_SIZE = 16
NEW_TOKENS = 25            # 24 decode steps after the prefill-sampled token
PROMPT_SIZES = (24, 40, 17, 56, 33, 48, 21, 60)


def _requests(cfg, n: int) -> list[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=NEW_TOKENS)
            for i, s in enumerate(PROMPT_SIZES[:n])]


def _run(cfg, params, n_reqs: int, **kw) -> tuple[list[Request], float,
                                                  PagedBatcher]:
    max_len = max(PROMPT_SIZES) + NEW_TOKENS
    pb = PagedBatcher(cfg, params,
                      num_blocks=1 + n_reqs * -(-max_len // BLOCK_SIZE),
                      block_size=BLOCK_SIZE,
                      max_blocks_per_seq=-(-max_len // BLOCK_SIZE),
                      decode_width=n_reqs, buckets=(32, 64),
                      cache_dtype=jnp.float32, **kw)
    reqs = _requests(cfg, n_reqs)
    t0 = time.perf_counter()
    pb.run(reqs)
    return reqs, time.perf_counter() - t0, pb


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))

    for n_reqs in (2, 4):
        reqs_h, dt_h, host = _run(cfg, params, n_reqs, sync="host")
        hs = host.stats()
        tok_h = sum(len(r.output) for r in reqs_h)
        emit(f"serve_sync.B{n_reqs}.host", dt_h * 1e6,
             f"dispatches={hs['decode_dispatches']};"
             f"decode_tokens={hs['decode_steps']};tok_s={tok_h / dt_h:.1f}")
        for window in (4, 8):
            reqs_d, dt_d, dev = _run(cfg, params, n_reqs, sync="device",
                                     window=window)
            ds = dev.stats()
            match = all(h.output == d.output
                        for h, d in zip(reqs_h, reqs_d))
            tok_d = sum(len(r.output) for r in reqs_d)
            saved = hs["decode_dispatches"] - ds["decode_dispatches"]
            emit(f"serve_sync.B{n_reqs}.device.w{window}", dt_d * 1e6,
                 f"dispatches={ds['decode_dispatches']};"
                 f"decode_tokens={ds['decode_steps']};"
                 f"tok_s={tok_d / dt_d:.1f};"
                 f"dispatches_saved={saved};match={match}")
            assert match, (f"B={n_reqs} w={window}: fused-window greedy "
                           "outputs diverged from host-synced arm")
            assert saved >= window, (
                f"B={n_reqs} w={window}: fused arm saved only {saved} "
                f"dispatches ({hs['decode_dispatches']} -> "
                f"{ds['decode_dispatches']}); expected >= {window}")

    emit_json("serve_sync")


if __name__ == "__main__":
    main()
