"""Paper Figs 16/17 — fast synchronization on/off.

Measured on this backend: per-token decode with the on-device lax.scan loop
("fast sync": zero host round-trips) vs the host-stepped loop with a forced
block_until_ready + device_get per token (the clFinish analogue). The paper
reports 2.2-4x decode speedups from fast sync; the same mechanism and
ordering reproduce here, scaled by this backend's dispatch cost.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.sync import (generate_host_loop, generate_on_device,
                             measure_dispatch_overhead)
from repro.models import build_model

from .common import emit, emit_json


def main() -> None:
    emit("sync/dispatch_overhead", measure_dispatch_overhead(), "per-dispatch")

    for arch in ("llama3-8b", "tinyllama-1.1b", "rwkv6-3b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                  cfg.vocab_size)
        n = 32

        def run(fast: bool):
            cache = model.init_cache(batch=1, max_len=128)
            _, cache = jax.block_until_ready(
                model.prefill(params, toks, cache))
            first = jnp.zeros((1, 1), jnp.int32)
            gen = generate_on_device if fast else generate_host_loop
            out = gen(model, params, first, cache, n)     # warm/compile
            cache2 = model.init_cache(batch=1, max_len=128)
            _, cache2 = jax.block_until_ready(
                model.prefill(params, toks, cache2))
            t0 = time.perf_counter()
            jax.block_until_ready(gen(model, params, first, cache2, n))
            return (time.perf_counter() - t0) / n * 1e6

        t_fast = run(True)
        t_host = run(False)
        emit(f"fig17_sync/{arch}/fast", t_fast,
             f"tok_s={1e6/t_fast:.1f}")
        emit(f"fig17_sync/{arch}/host", t_host,
             f"tok_s={1e6/t_host:.1f},fast_speedup={t_host/t_fast:.2f}x")

    emit_json("sync")


if __name__ == "__main__":
    main()
