"""Paper Fig 14 — dynamic/misaligned sequence lengths: Online-prepare vs
Padding vs NPU-pipe vs Hetero (activation-centric + hybrid).

Analytic arm: per-op solver latencies + the compile-cost model for
Online-prepare's per-shape graph generation. Measured arm: the real engine's
four prefill strategies on the smoke model, including actual jit compile
time paid by online-prepare.
"""
from __future__ import annotations

import jax

from repro.configs import get_config, get_smoke_config
from repro.core.characteristics import compile_time_model_us
from repro.core.engine import InferenceEngine
from repro.core.profiler import STANDARD_BUCKETS, profile_analytic
from repro.core.solver import PartitionSolver

from .common import emit, emit_json

SEQS = (135, 300, 525, 1000)


def analytic_arm(arch: str = "llama3-8b"):
    cfg = get_config(arch)
    table = profile_analytic(cfg)
    solver = PartitionSolver(table, sync_mode="fast")
    sites = [s for s in table.sites if s != "head"]
    for S in SEQS:
        bucket = next((b for b in STANDARD_BUCKETS if b >= S),
                      STANDARD_BUCKETS[-1])
        # online-prepare: exact-shape compute + per-shape graph build
        t_exact = sum(table.lookup(s, S, "mxu") for s in sites) * cfg.n_layers
        t_onlineprep = t_exact + 4 * compile_time_model_us(
            S, cfg.d_model, cfg.d_ff)
        # padding: everything on the aligned path at the padded bucket
        t_pad = sum(table.lookup(s, bucket, "mxu")
                    for s in sites) * cfg.n_layers
        # pipe: sequential standard chunks (+ padded tail), aligned path only
        t_pipe = 0.0
        rem = S
        for b in sorted(STANDARD_BUCKETS, reverse=True):
            while rem >= b:
                t_pipe += sum(table.lookup(s, b, "mxu") for s in sites)
                rem -= b
        if rem:
            t_pipe += sum(table.lookup(s, min(STANDARD_BUCKETS), "mxu")
                          for s in sites)
        t_pipe *= cfg.n_layers
        # hetero: solver-chosen act/hybrid partitioning at exact S
        t_het = sum(solver.solve_site(s, S).t_us for s in sites) * cfg.n_layers
        base = t_het
        emit(f"fig14_dynamic/{arch}/S={S}/online-prepare", t_onlineprep,
             f"vs_hetero={t_onlineprep/base:.2f}x")
        emit(f"fig14_dynamic/{arch}/S={S}/padding", t_pad,
             f"vs_hetero={t_pad/base:.2f}x")
        emit(f"fig14_dynamic/{arch}/S={S}/pipe", t_pipe,
             f"vs_hetero={t_pipe/base:.2f}x")
        emit(f"fig14_dynamic/{arch}/S={S}/hetero", t_het, "1.00x")


def measured_arm():
    cfg = get_smoke_config("llama3-8b")
    import time
    for strat in ("online-prepare", "padding", "pipe", "hetero"):
        eng = InferenceEngine(cfg, mode="xla", prefill_strategy=strat,
                              buckets=(64, 128, 256), max_len=1400)
        total = 0.0
        for S in SEQS:
            prompt = jax.random.randint(jax.random.PRNGKey(S), (1, S), 0,
                                        cfg.vocab_size)
            t0 = time.perf_counter()
            eng.generate(prompt, max_new_tokens=1)
            total += time.perf_counter() - t0
        emit(f"fig14_dynamic_measured/{strat}", total * 1e6,
             f"compiles={eng.stats.n_compiles},compile_s={eng.stats.compile_s:.2f}")


def main() -> None:
    analytic_arm()
    measured_arm()

    emit_json("dynamic")


if __name__ == "__main__":
    main()
