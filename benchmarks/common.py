"""Shared benchmark utilities. Every benchmark prints CSV rows
``name,us_per_call,derived`` so benchmarks.run can aggregate them."""
from __future__ import annotations

import time

import jax
import numpy as np


def bench(fn, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (on the current backend)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
