"""Shared benchmark utilities. Every benchmark prints CSV rows
``name,us_per_call,derived`` (``emit``) so benchmarks.run can aggregate
them, and finishes with ``emit_json(<bench>)`` so the same rows land in a
machine-readable ``BENCH_<bench>.json`` at the repo root — the perf
trajectory artifact CI and the aggregator (`benchmarks/run.py`) consume."""
from __future__ import annotations

import json
import subprocess
import time
from functools import lru_cache
from pathlib import Path

import jax
import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent

# bump when the BENCH_*.json payload shape changes so trajectory tooling
# can tell apart artifacts written by different repo generations
# (2: added the ``repolint_clean`` lint-attestation field)
SCHEMA_VERSION = 2


@lru_cache(maxsize=1)
def git_sha() -> str:
    """Short SHA of the repo HEAD, or ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
    except OSError:
        return "unknown"
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"


@lru_cache(maxsize=1)
def repolint_clean() -> bool:
    """Whether the tree the bench ran on passes repolint — stamped into
    every BENCH_*.json so perf artifacts attest the code they measured
    held the repo's static invariants (donation safety, determinism,
    jit hygiene, sync discipline)."""
    try:
        from repro.analysis import run_repolint
        return run_repolint(REPO_ROOT).ok
    except Exception:
        return False

# rows emitted since the last emit_json() call: emit() records every CSV row
# here so benches don't have to thread their results twice
_ROWS: list[dict] = []
# files emit_json() wrote during THIS process — what run.py aggregates, so
# stale artifacts from earlier runs or removed benches are never folded in
_WRITTEN: list[Path] = []


def reset_rows() -> None:
    """Drop rows buffered by a failed bench so the next module's
    ``emit_json`` can't misattribute them (run.py calls this on failure)."""
    _ROWS.clear()


def bench(fn, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (on the current backend)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us": round(float(us), 1),
                  "derived": derived})


def emit_json(name: str, metrics: dict | None = None) -> Path:
    """Write ``BENCH_<name>.json`` at the repo root: every ``emit()`` row
    since the previous ``emit_json()`` plus optional headline ``metrics``
    (the numbers a trajectory plot would track). Returns the path."""
    global _ROWS
    rows, _ROWS = _ROWS, []
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(
        {"bench": name, "schema_version": SCHEMA_VERSION,
         "git_sha": git_sha(), "repolint_clean": repolint_clean(),
         "metrics": metrics or {}, "rows": rows},
        indent=1))
    _WRITTEN.append(path)
    return path
