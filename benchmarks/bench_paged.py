"""Paged vs dense-slot KV cache at EQUAL cache memory (serving tentpole).

Both arms get a KV budget of ``POOL_TOKENS`` token-slots per layer. The
dense continuous batcher spends it as ``max_batch x max_len`` worst-case
slots; the paged batcher spends it as a shared block pool sized by actual
request need. On a workload of short requests the paged arm sustains
strictly higher peak concurrency and throughput, while greedy outputs
match the dense arm token-for-token (paging is an allocation policy, never
a numerics change — same invariant the engine arms assert).

Rows: ``paged_kv.<arm>,us_total,reqs=..;peak=..;tok_s=..;match=..``
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.scheduler import ContinuousBatcher, PagedBatcher, Request

MAX_LEN = 256           # dense worst-case per-slot length
BLOCK_SIZE = 32
POOL_TOKENS = 2 * MAX_LEN   # equal-memory budget: dense fits 2 slots
N_REQS = 8
NEW_TOKENS = 8


def _requests(cfg) -> list[Request]:
    rng = np.random.default_rng(0)
    sizes = [24, 40, 17, 56, 33, 48, 21, 60][:N_REQS]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=NEW_TOKENS)
            for i, s in enumerate(sizes)]


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))

    dense = ContinuousBatcher(cfg, params,
                              max_batch=POOL_TOKENS // MAX_LEN,
                              max_len=MAX_LEN, buckets=(32, 64))
    dense.cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        dense.cache)
    reqs_d = _requests(cfg)
    t0 = time.perf_counter()
    dense.run(reqs_d)
    dt_d = time.perf_counter() - t0

    paged = PagedBatcher(cfg, params,
                         num_blocks=POOL_TOKENS // BLOCK_SIZE,
                         block_size=BLOCK_SIZE,
                         max_blocks_per_seq=MAX_LEN // BLOCK_SIZE,
                         decode_width=N_REQS,
                         buckets=(32, 64), cache_dtype=jnp.float32)
    reqs_p = _requests(cfg)
    t0 = time.perf_counter()
    paged.run(reqs_p)
    dt_p = time.perf_counter() - t0

    match = all(d.output == p.output for d, p in zip(reqs_d, reqs_p))
    tok_d = sum(len(r.output) for r in reqs_d)
    tok_p = sum(len(r.output) for r in reqs_p)
    emit("paged_kv.dense", dt_d * 1e6,
         f"reqs={N_REQS};peak={dense.peak_active};"
         f"tok_s={tok_d / dt_d:.1f};mem_tokens={POOL_TOKENS}")
    emit("paged_kv.paged", dt_p * 1e6,
         f"reqs={N_REQS};peak={paged.peak_active};"
         f"tok_s={tok_p / dt_p:.1f};mem_tokens={paged.kv.memory_tokens()};"
         f"match={match}")
    assert match, "paged greedy outputs diverged from dense"
    assert paged.peak_active > dense.peak_active, (
        f"paged peak {paged.peak_active} <= dense peak {dense.peak_active} "
        "at equal memory")

    emit_json("paged")


if __name__ == "__main__":
    main()
