"""Paper Fig 12 + Table 4 — end-to-end latency on the three workload mixes:
multi-turn dialogue (BELLE: 54 prefill / 374 decode), simple QA (GSM8K:
296/340), long-text (LongBench: 1787/5). Analytic arm (llama3-8b on v5e)
across the four engine arms; plus a measured smoke-scale run.
"""
from __future__ import annotations

import jax

from repro.configs import get_config, get_smoke_config
from repro.core.characteristics import V5E, sync_cost_us
from repro.core.engine import InferenceEngine
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver

from .common import emit, emit_json

WORKLOADS = {            # Table 4
    "dialogue": (54, 374),
    "gsm8k": (296, 340),
    "longbench": (1787, 5),
}


def main() -> None:
    cfg = get_config("llama3-8b")
    table = profile_analytic(cfg)
    solver = PartitionSolver(table, sync_mode="fast")
    sites = [s for s in table.sites if s != "head"]
    spec = V5E
    w_bytes = cfg.n_params_active * 2

    def decode_us(per_tok_bw_frac, sync_us):
        return (w_bytes / (spec.hbm_bw * per_tok_bw_frac) * 1e6
                + sync_us * cfg.n_layers)

    for wname, (p_tok, d_tok) in WORKLOADS.items():
        arms = {}
        t_xla_prefill = sum(table.lookup(s, p_tok, "xla")
                            for s in sites) * cfg.n_layers
        arms["xla_only"] = (t_xla_prefill
                            + d_tok * decode_us(spec.bw_frac_single, 0.0))
        t_mxu_prefill = sum(table.lookup(s, p_tok, "mxu")
                            for s in sites) * cfg.n_layers
        arms["mxu_only"] = (t_mxu_prefill
                            + d_tok * decode_us(spec.bw_frac_single,
                                                sync_cost_us("host")))
        t_het_prefill = sum(solver.solve_site(s, p_tok).t_us
                            for s in sites) * cfg.n_layers
        arms["hetero"] = (t_het_prefill
                          + d_tok * decode_us(spec.bw_frac_dual,
                                              sync_cost_us("fast")))
        base = arms["hetero"]
        for arm, t in arms.items():
            emit(f"fig12_e2e/{wname}/{arm}", t,
                 f"speedup_of_hetero={t/base:.2f}x")

    # measured smoke-scale end-to-end (mechanism check)
    scfg = get_smoke_config("llama3-8b")
    prompt = jax.random.randint(jax.random.PRNGKey(0), (1, 54), 0,
                                scfg.vocab_size)
    for mode, fast in (("xla", False), ("hetero-tensor", True)):
        eng = InferenceEngine(scfg, mode=mode, fast_sync=fast, max_len=512)
        eng.generate(prompt, max_new_tokens=8)     # warm
        eng.stats.prefill_s = eng.stats.decode_s = 0.0
        eng.generate(prompt, max_new_tokens=32)
        emit(f"fig12_e2e_measured/dialogue/{mode}",
             (eng.stats.prefill_s + eng.stats.decode_s) * 1e6,
             f"fast_sync={fast}")

    emit_json("e2e")


if __name__ == "__main__":
    main()
