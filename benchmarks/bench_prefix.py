"""Automatic prefix caching vs cold-path prefill on shared-system-prompt
traffic (the dominant on-device assistant pattern: thousands of requests,
one system prompt).

Two identical workloads — a warm-up request followed by a wave of requests
sharing its system prompt — run through the paged batcher with the prefix
cache OFF (cold arm: every prompt re-prefills from scratch) and ON (warm
arm: admission shares the hash-matched blocks and prefills only the
uncached suffix). Asserted properties, on BOTH sync arms (host-synced and
fused-window decode):

  * greedy outputs bit-identical between the cold and warm arms (cached KV
    was computed from the same tokens at the same positions — reuse is an
    allocation-policy change, never a numerics change);
  * strictly fewer prefill dispatches on the warm arm;
  * strictly fewer fresh pool blocks allocated on the warm arm
    (``allocator.total_allocs`` — the capacity lever);
  * ``stats()['prefix_hits'] > 0`` and tokens actually reused.

Rows: ``prefix.<sync>.<arm>,us_total,...`` + solver-visible counters.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request

BLOCK_SIZE = 16
NUM_BLOCKS = 41
SYS_PROMPT_LEN = 48            # 3 full blocks shared by every request
TAIL_LENS = (7, 13, 0, 16, 29)  # wave tails; 0 = full-prompt hit (CoW path)
NEW_TOKENS = 6
DECODE_WIDTH = 3


def _waves(cfg) -> tuple[list[Request], list[Request]]:
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, cfg.vocab_size,
                              SYS_PROMPT_LEN).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, t).astype(np.int32)
             for t in TAIL_LENS]
    warmup = [Request(rid=0, prompt=np.concatenate([sys_prompt, tails[0]]),
                      max_new_tokens=NEW_TOKENS)]
    wave = [Request(rid=i + 1, prompt=np.concatenate([sys_prompt, t]),
                    max_new_tokens=NEW_TOKENS)
            for i, t in enumerate(tails)]
    return warmup, wave


def _run_arm(cfg, params, *, sync: str, prefix_cache: bool):
    pb = PagedBatcher(cfg, params, num_blocks=NUM_BLOCKS,
                      block_size=BLOCK_SIZE, decode_width=DECODE_WIDTH,
                      buckets=(32, 64), cache_dtype=jnp.float32,
                      sync=sync, window=3, prefix_cache=prefix_cache)
    warmup, wave = _waves(cfg)
    t0 = time.perf_counter()
    pb.run(warmup)
    pb.run(wave)
    dt = time.perf_counter() - t0
    pb.kv.assert_drained()
    return pb, warmup + wave, dt


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))

    metrics = {}
    for sync in ("host", "device"):
        cold, reqs_c, dt_c = _run_arm(cfg, params, sync=sync,
                                      prefix_cache=False)
        warm, reqs_w, dt_w = _run_arm(cfg, params, sync=sync,
                                      prefix_cache=True)
        match = all(c.output == w.output for c, w in zip(reqs_c, reqs_w))
        sc, sw = cold.stats(), warm.stats()
        blocks_c = cold.kv.allocator.total_allocs
        blocks_w = warm.kv.allocator.total_allocs
        emit(f"prefix.{sync}.cold", dt_c * 1e6,
             f"reqs={len(reqs_c)};prefill_disp={sc['prefill_dispatches']};"
             f"blocks_alloc={blocks_c}")
        emit(f"prefix.{sync}.warm", dt_w * 1e6,
             f"reqs={len(reqs_w)};prefill_disp={sw['prefill_dispatches']};"
             f"blocks_alloc={blocks_w};hits={sw['prefix_hits']};"
             f"tokens_reused={sw['prefix_tokens_reused']};"
             f"cow={sw['cow_copies']};evictions={sw['evictions']};"
             f"match={match}")
        assert match, f"{sync}: warm greedy outputs diverged from cold"
        assert sw["prefill_dispatches"] < sc["prefill_dispatches"], (
            f"{sync}: warm prefill dispatches {sw['prefill_dispatches']} "
            f"not < cold {sc['prefill_dispatches']}")
        assert blocks_w < blocks_c, (
            f"{sync}: warm fresh-block allocs {blocks_w} "
            f"not < cold {blocks_c}")
        assert sw["prefix_hits"] > 0 and sw["prefix_tokens_reused"] > 0
        assert sc["prefix_hits"] == 0      # cold arm never hits
        metrics[sync] = {
            "prefill_dispatches_cold": sc["prefill_dispatches"],
            "prefill_dispatches_warm": sw["prefill_dispatches"],
            "blocks_alloc_cold": blocks_c,
            "blocks_alloc_warm": blocks_w,
            "prefix_hits": sw["prefix_hits"],
            "prefix_tokens_reused": sw["prefix_tokens_reused"],
            "match": match,
        }

    emit_json("prefix", metrics)


if __name__ == "__main__":
    main()
