"""Paper Fig 5 — Memory-1: aggregated bandwidth with concurrent streams.

Analytic: the v5e single- vs dual-stream achievable-bandwidth model used by
the solver. Measured: single large memcopy-like jnp op vs two independent
ops dispatched together (XLA overlaps independent HBM streams) on this
backend — the mechanism the decode-phase weight split exploits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.characteristics import V5E

from .common import bench, emit, emit_json


def main() -> None:
    spec = V5E
    emit("fig5_bw_model/single", 0.0,
         f"GBs={spec.hbm_bw*spec.bw_frac_single/1e9:.0f}")
    emit("fig5_bw_model/dual", 0.0,
         f"GBs={spec.hbm_bw*spec.bw_frac_dual/1e9:.0f}")
    emit("fig5_bw_model/peak", 0.0, f"GBs={spec.hbm_bw/1e9:.0f}")

    n = 1 << 22
    a = jnp.arange(n, dtype=jnp.float32)
    b = jnp.arange(n, dtype=jnp.float32) * 2

    one = jax.jit(lambda x: x * 1.0001)
    two = jax.jit(lambda x, y: (x * 1.0001, y * 1.0001))

    t1 = bench(one, a)
    t2 = bench(two, a, b)
    bw1 = n * 8 / t1 / 1e3            # read+write GB/s
    bw2 = 2 * n * 8 / t2 / 1e3
    emit("fig5_bw_measured/one_stream", t1, f"GBs={bw1:.1f}")
    emit("fig5_bw_measured/two_streams", t2,
         f"GBs={bw2:.1f},aggregation={bw2/bw1:.2f}x")

    emit_json("bandwidth")


if __name__ == "__main__":
    main()
