"""Paper Table 3 — solver inputs/outputs on the paper's exact weight/
activation shapes (Llama-family sites). Emits one row per (weight shape,
activation M) with the chosen strategy + partition ratio.
"""
from __future__ import annotations

from repro.core.characteristics import V5E
from repro.core.profiler import LatencyTable
from repro.core.solver import PartitionSolver

from .common import emit, emit_json

PAPER_ROWS = [
    # (K, N, M) — [weight shape], activation tokens (paper Table 3)
    (4096, 4096, 1),
    (4096, 28672, 1),           # fused up+gate
    (14336, 4096, 1),           # FFN-down
    (4096, 4096, 128),
    (4096, 4096, 224),          # inside the 193-255 padding band
    (4096, 4096, 256),
    (4096, 4096, 264),          # 257-272: activation-centric band
    (14336, 4096, 256),
    (14336, 4096, 320),         # 257-384: hybrid band
]


def main() -> None:
    table = LatencyTable(spec=V5E, mode="analytic")
    table.sites = {f"w{K}x{N}": (K, N) for K, N, _ in PAPER_ROWS}
    solver = PartitionSolver(table, sync_mode="fast")
    for K, N, M in PAPER_ROWS:
        d = solver.solve_site(f"w{K}x{N}", M)
        emit(f"table3/[{K}x{N}]xM{M}", d.t_us,
             f"{d.strategy}({d.ratio})")

    emit_json("solver_table")


if __name__ == "__main__":
    main()
