"""Paper Fig 15 — decode rate across engine arms.

Decode is bandwidth-bound (Memory-1): the analytic arm reports tokens/s from
the weights+KV byte stream over the achievable bandwidth of each arm —
single-stream for xla/mxu-only, dual-stream aggregated for hetero — exactly
the paper's explanation for its 43.3 -> 59.5 GB/s gain.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.characteristics import V5E
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver

from .common import emit, emit_json


def main() -> None:
    spec = V5E
    for arch in ("llama3-8b", "tinyllama-1.1b", "internlm-1.8b", "rwkv6-3b"):
        cfg = get_config(arch)
        kv_len = 256
        w_bytes = cfg.n_params_active * 2
        if cfg.rwkv is None:
            kv_bytes = (2 * cfg.n_layers * kv_len * cfg.n_kv_heads
                        * cfg.head_dim * 2)
        else:
            kv_bytes = cfg.n_layers * cfg.d_model * 64 * 4     # wkv state
        tot = w_bytes + kv_bytes
        t_single = tot / (spec.hbm_bw * spec.bw_frac_single)
        t_dual = tot / (spec.hbm_bw * spec.bw_frac_dual)
        emit(f"fig15_decode_model/{arch}/single_engine", t_single * 1e6,
             f"tok_s={1/t_single:.1f}")
        emit(f"fig15_decode_model/{arch}/hetero_dual", t_dual * 1e6,
             f"tok_s={1/t_dual:.1f},speedup={t_single/t_dual:.2f}x")
        # solver confirms: decode sites choose dual-path weight splits
        table = profile_analytic(cfg)
        solver = PartitionSolver(table, sync_mode="fast")
        strategies = {s: solver.solve_site(s, 1).strategy
                      for s in table.sites if s != "head"}
        n_part = sum(1 for v in strategies.values()
                     if v in ("weight", "act", "hybrid"))
        emit(f"fig15_decode_model/{arch}/partitioned_sites", 0.0,
             f"{n_part}/{len(strategies)}")

    emit_json("decode")


if __name__ == "__main__":
    main()
