"""Benchmark aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit). Each
module also writes its machine-readable ``BENCH_<name>.json`` at the repo
root (common.emit_json); after the sweep this aggregator folds them into
``BENCH_summary.json`` — the perf-trajectory artifact."""
from __future__ import annotations

import importlib
import json
import sys
import traceback

import jax

from benchmarks import common
from benchmarks.common import REPO_ROOT

MODULES = [
    "benchmarks.bench_characteristics",   # Figs 1/3/4
    "benchmarks.bench_bandwidth",         # Fig 5
    "benchmarks.bench_compile_cost",      # Fig 8
    "benchmarks.bench_solver_table",      # Table 3
    "benchmarks.bench_prefill",           # Fig 13
    "benchmarks.bench_dynamic",           # Fig 14
    "benchmarks.bench_decode",            # Fig 15
    "benchmarks.bench_sync",              # Figs 16/17
    "benchmarks.bench_ablation",          # Fig 18
    "benchmarks.bench_e2e",               # Fig 12 + Table 4
    "benchmarks.bench_paged",             # paged vs dense KV at equal memory
    "benchmarks.bench_serve_sync",        # host-synced vs fused-window decode
    "benchmarks.bench_mixed_batch",       # stage-parallel prefill⊕decode fusion
    "benchmarks.bench_spec",              # speculative decoding vs plain decode
    "benchmarks.bench_prefix",            # prefix caching vs cold prefill
    "benchmarks.bench_open_loop",         # open-loop TTFT/TPOT percentiles
    "benchmarks.bench_quant",             # quantized weights + int8 KV pool
    "benchmarks.bench_tp",                # tensor-parallel paged serving
    "benchmarks.bench_observability",     # tracing determinism + plan drift
    "benchmarks.roofline_report",         # §Roofline
]


def aggregate() -> dict:
    """Fold the BENCH_<name>.json files written during THIS run into one
    summary dict and write BENCH_summary.json. Only files emit_json()
    produced this process count — a failed bench, or a stale artifact from
    an earlier run or a removed bench, is never folded in."""
    benches = {}
    for path in common._WRITTEN:
        data = json.loads(path.read_text())
        benches[data["bench"]] = {"metrics": data["metrics"],
                                  "n_rows": len(data["rows"])}
    summary = {"benches": benches, "n_benches": len(benches),
               "schema_version": common.SCHEMA_VERSION,
               "git_sha": common.git_sha()}
    (REPO_ROOT / "BENCH_summary.json").write_text(
        json.dumps(summary, indent=1))
    return summary


def main() -> None:
    failures = []
    for name in MODULES:
        print(f"# ---- {name} ----")
        try:
            importlib.import_module(name).main()
        except Exception as e:  # noqa: BLE001 — report and continue the suite
            failures.append((name, e))
            print(f"# {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
            common.reset_rows()   # don't leak this bench's rows into the
            #                       next module's BENCH_<name>.json
        # compiled executables pin mmapped code pages; a full sweep in one
        # process can exhaust vm.max_map_count (jaxlib segfaults in
        # backend_compile) — drop each module's executables before the next
        jax.clear_caches()
    summary = aggregate()
    print(f"# ---- aggregate: {summary['n_benches']} BENCH_*.json -> "
          "BENCH_summary.json ----")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
