"""Benchmark aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit)."""
from __future__ import annotations

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.bench_characteristics",   # Figs 1/3/4
    "benchmarks.bench_bandwidth",         # Fig 5
    "benchmarks.bench_compile_cost",      # Fig 8
    "benchmarks.bench_solver_table",      # Table 3
    "benchmarks.bench_prefill",           # Fig 13
    "benchmarks.bench_dynamic",           # Fig 14
    "benchmarks.bench_decode",            # Fig 15
    "benchmarks.bench_sync",              # Figs 16/17
    "benchmarks.bench_ablation",          # Fig 18
    "benchmarks.bench_e2e",               # Fig 12 + Table 4
    "benchmarks.bench_paged",             # paged vs dense KV at equal memory
    "benchmarks.bench_serve_sync",        # host-synced vs fused-window decode
    "benchmarks.bench_mixed_batch",       # stage-parallel prefill⊕decode fusion
    "benchmarks.roofline_report",         # §Roofline
]


def main() -> None:
    failures = []
    for name in MODULES:
        print(f"# ---- {name} ----")
        try:
            importlib.import_module(name).main()
        except Exception as e:  # noqa: BLE001 — report and continue the suite
            failures.append((name, e))
            print(f"# {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
