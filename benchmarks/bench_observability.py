"""Observability harness benchmark: tracing determinism, counter/trace
reconciliation across scheduler arms, and the solver plan-drift report.

Every other benchmark measures the serving stack; this one measures the
INSTRUMENT. Under ``FakeClock`` + a deterministic ``cost_model`` (virtual
dispatch costs derived from the solver's own predictions), the tracer must
behave as a measuring device CI can pin:

  * ``identical_reruns``  — the same arm traced twice produces BYTE-identical
    Chrome trace JSON and Prometheus snapshots (the artifact-determinism
    contract tier-1 relies on);
  * per-arm reconciliation — on host-sync, fused-window and mixed arms the
    tracer's mirrored counters equal the scheduler's ``stats()`` ledger
    exactly, and per-kind B-event counts equal the dispatch counters;
  * ``drift_rows``        — the engine-mode arm's plan-drift report carries a
    (site, M, strategy) residual row for every solver decision exercised;
  * ``overhead_off``      — with tracing off (the default NULL_TRACER) the
    run records ZERO events and emits token streams identical to the traced
    run (observation only, in both directions).

Rows: ``observability.<arm>.{events,dispatches,drift_rows}`` plus the
determinism/overhead booleans. ``BENCH_observability.json`` carries the
full drift report of the engine-mode arm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request
from repro.serving.telemetry import FakeClock
from repro.serving.trace import NULL_TRACER, Tracer, counter_reconciliation

BS = 16
N_REQ = 4
PROMPT_LENS = (11, 26, 40, 18)
BUDGETS = (6, 4, 7, 5)

ARMS = {
    "host": dict(sync="host", engine_mode="hetero-tensor"),
    "device_window": dict(sync="device", window=3,
                          engine_mode="hetero-tensor"),
    "mixed": dict(sync="device", window=3, mixed_batch=True,
                  engine_mode="hetero-tensor"),
}


def _cost_model(kind, predicted_us):
    return max(predicted_us, 10.0) * 1e-6


def _run(cfg, params, *, tracer, **kw):
    max_len = max(PROMPT_LENS) + max(BUDGETS) + 1
    pb = PagedBatcher(cfg, params,
                      num_blocks=1 + N_REQ * -(-max_len // BS),
                      block_size=BS, max_blocks_per_seq=-(-max_len // BS),
                      decode_width=3, buckets=(32, 64),
                      cache_dtype=jnp.float32, tracer=tracer, **kw)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s
                                        ).astype(np.int32),
                    max_new_tokens=m)
            for i, (s, m) in enumerate(zip(PROMPT_LENS, BUDGETS))]
    pb.run(reqs)
    pb.kv.assert_drained()
    return pb, [list(r.output) for r in reqs]


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    metrics: dict = {}

    outputs = {}
    drift_report = None
    for arm, kw in ARMS.items():
        tracer = Tracer(FakeClock(), cost_model=_cost_model)
        pb, out = _run(cfg, params, tracer=tracer, **kw)
        outputs[arm] = out
        st = pb.stats()
        mism = counter_reconciliation(tracer, st)
        assert mism == {}, f"{arm}: tracer/stats ledgers diverged: {mism}"
        by_kind = {}
        for e in tracer.events:
            if e["ph"] == "B" and e.get("cat") == "dispatch":
                by_kind[e["name"]] = by_kind.get(e["name"], 0) + 1
        assert by_kind.get("prefill_chunk", 0) == st["prefill_dispatches"]
        assert sum(by_kind.get(k, 0) for k in
                   ("decode_step", "decode_window", "mixed_step",
                    "mixed_window", "paged_verify")) \
            == st["decode_dispatches"], (arm, by_kind)
        assert tracer.dropped == 0
        n_rows = len(tracer.drift.report()["rows"])
        plan_sites = {s for (s, _) in pb.ctx.plan.decisions}
        assert {r["site"] for r in tracer.drift.report()["rows"]} \
            == plan_sites, arm
        emit(f"observability.{arm}.events", tracer.n_events,
             f"dispatches={sum(by_kind.values())};drift_rows={n_rows}")
        metrics[arm] = {"events": tracer.n_events,
                        "dispatches": sum(by_kind.values()),
                        "drift_rows": n_rows,
                        "reconciled": True}
        if arm == "device_window":
            drift_report = tracer.drift.report()
            print(tracer.drift.format_table())

    # determinism: trace the device arm twice -> byte-identical artifacts
    # (serialize exactly as save_chrome does, compared in memory)
    import json
    blobs, proms = [], []
    for _ in range(2):
        tracer = Tracer(FakeClock(), cost_model=_cost_model)
        _run(cfg, params, tracer=tracer, **ARMS["device_window"])
        blobs.append(json.dumps(tracer.to_chrome(), sort_keys=True,
                                separators=(",", ":")) + "\n")
        proms.append(tracer.to_prometheus())
    assert blobs[0] == blobs[1], "trace artifact not byte-reproducible"
    assert proms[0] == proms[1], "metrics snapshot not byte-reproducible"
    emit("observability.rerun.identical", 1,
         f"trace_bytes={len(blobs[0])}")
    metrics["identical_reruns"] = {"trace_bytes": len(blobs[0]),
                                   "prom_bytes": len(proms[0])}

    # tracing off: the default batcher records nothing and emits the same
    # tokens as the traced arm
    pb_off, out_off = _run(cfg, params, tracer=None, **ARMS["device_window"])
    assert pb_off.tracer is NULL_TRACER
    assert out_off == outputs["device_window"], (
        "tracing changed token output")
    emit("observability.off.events", 0, "null_tracer")
    metrics["overhead_off"] = {"events": 0,
                              "tokens_identical": True}

    emit_json("observability", {**metrics,
                                "drift": drift_report})


if __name__ == "__main__":
    main()
