"""Paper Fig 18 — cumulative ablation at prompt length 320 (the paper's
setting): naive-MXU (online-prepare) -> +activation-centric -> +order
exchange -> +weight-centric -> +fast sync. Analytic arm on llama3-8b;
the measured engine arms are covered by bench_dynamic / bench_sync.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.characteristics import (combine_dual, compile_time_model_us,
                                        mxu_matmul_parts, mxu_matmul_time_us,
                                        sync_cost_us, xla_matmul_parts)
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver

from .common import emit, emit_json

S = 320


def main() -> None:
    cfg = get_config("llama3-8b")
    table = profile_analytic(cfg)
    sites = {s: kn for s, kn in table.sites.items() if s != "head"}
    L = cfg.n_layers

    # (0) naive NPU: online graph generation per shape + misaligned exec
    naive = sum(mxu_matmul_time_us(S, K, N) for K, N in sites.values()) * L \
        + 4 * compile_time_model_us(S, cfg.d_model, cfg.d_ff)
    emit("fig18_ablation/naive_mxu", naive, "1.00x")

    # (1) + activation-centric: bucket 256 on MXU + 64 remainder on XLA
    act = sum(combine_dual(mxu_matmul_parts(256, K, N),
                           xla_matmul_parts(S - 256, K, N))
              + sync_cost_us("fast")
              for K, N in sites.values()) * L
    emit("fig18_ablation/act_centric", act, f"{naive/act:.2f}x cumulative")

    # (2) + order exchange: operand orientation chosen per NPU-2 by total
    # single-path time (compute AND reload-traffic trade-off)
    from repro.core.characteristics import combine_single
    ord_ = sum(combine_dual(
        min(mxu_matmul_parts(256, K, N), mxu_matmul_parts(N, K, 256),
            key=lambda p: combine_single(p)),
        xla_matmul_parts(S - 256, K, N)) + sync_cost_us("fast")
        for K, N in sites.values()) * L
    emit("fig18_ablation/order_exchange", ord_, f"{naive/ord_:.2f}x cumulative")

    # (3) + weight-centric/hybrid: full solver
    solver = PartitionSolver(table, sync_mode="fast")
    het = sum(solver.solve_site(s, S).t_us for s in sites) * L
    emit("fig18_ablation/weight_centric", het, f"{naive/het:.2f}x cumulative")

    # (4) + fast sync vs host sync on the final config
    solver_h = PartitionSolver(table, sync_mode="host")
    het_h = sum(solver_h.solve_site(s, S).t_us for s in sites) * L
    emit("fig18_ablation/fast_sync_final", het,
         f"{het_h/het:.2f}x from sync alone")

    emit_json("ablation")


if __name__ == "__main__":
    main()
