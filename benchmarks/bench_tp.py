"""Tensor-parallel paged serving vs TP=1 (serving/layout.py tentpole).

On real accelerators TP buys capacity and aggregate bandwidth, not
different math — so on the virtual-CPU mesh this bench pins the three
claims that survive the backend:

  * bit-exact: the TP=2 greedy streams match TP=1 token for token (the
    column-parallel layout only concatenates output slices — no reduction
    is reassociated);
  * the dispatch protocol is TP-invariant: decode/prefill/total host
    dispatch counts are IDENTICAL to TP=1 — each dispatch simply spans the
    mesh, so fused-window amortization composes with sharding unchanged;
  * equal-total-memory scaling: per-device weight bytes and per-device KV
    pool bytes drop ~1/TP (norms/embed and the int8 scale planes
    replicate), i.e. at equal per-device memory a TP=N mesh serves an
    ~N-times larger model or an ~N-times larger shared pool.

Rows: ``tp.serve_tp<N>,us_total,reqs=..;tok_s=..;dispatches=..;match=..``
and ``tp.<weights|pool>_per_device,bytes_tp1,tp2=..;ratio=..``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request

BLOCK_SIZE = 16
N_REQS = 4
NEW_TOKENS = 8


def _requests(cfg) -> list[Request]:
    rng = np.random.default_rng(0)
    sizes = [24, 40, 17, 33][:N_REQS]
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=NEW_TOKENS)
            for i, s in enumerate(sizes)]


def _per_device_bytes(tree) -> int:
    """Bytes device 0 holds: one shard per leaf under a NamedSharding,
    the whole array when replicated / unplaced."""
    return sum(leaf.addressable_shards[0].data.nbytes
               for leaf in jax.tree.leaves(tree))


def _serve(cfg, params, mesh=None):
    b = PagedBatcher(cfg, params, num_blocks=24, block_size=BLOCK_SIZE,
                     max_blocks_per_seq=4, decode_width=N_REQS,
                     sync="device", window=4, buckets=(32, 64),
                     cache_dtype=jnp.float32, mesh=mesh)
    reqs = _requests(cfg)
    t0 = time.perf_counter()
    b.run(reqs)
    dt = time.perf_counter() - t0
    b.kv.assert_drained()
    return b, reqs, dt


def main() -> None:
    if len(jax.devices()) < 2:
        # the mesh needs >= 2 devices (CI exports
        # --xla_force_host_platform_device_count before any jax import)
        emit("tp.skipped", 0.0, f"devices={len(jax.devices())}")
        emit_json("tp", {"skipped": True})
        return
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))

    b1, reqs1, dt1 = _serve(cfg, params)
    b2, reqs2, dt2 = _serve(cfg, params, mesh=make_host_mesh(1, 2))

    match = all(a.output == b.output for a, b in zip(reqs1, reqs2))
    disp = (b1.decode_dispatches, b1.prefill_dispatches, b1.total_dispatches)
    disp2 = (b2.decode_dispatches, b2.prefill_dispatches,
             b2.total_dispatches)
    tok = sum(len(r.output) for r in reqs1)
    emit("tp.serve_tp1", dt1 * 1e6,
         f"reqs={N_REQS};tok_s={tok / dt1:.1f};dispatches={disp}")
    emit("tp.serve_tp2", dt2 * 1e6,
         f"reqs={N_REQS};tok_s={tok / dt2:.1f};dispatches={disp2};"
         f"match={match}")
    assert match, "TP=2 greedy streams diverged from TP=1"
    assert disp == disp2, (
        f"TP changed the dispatch protocol: {disp} != {disp2}")

    wb1, wb2 = _per_device_bytes(b1.params), _per_device_bytes(b2.params)
    pb1, pb2 = _per_device_bytes(b1.kv.pool), _per_device_bytes(b2.kv.pool)
    emit("tp.weights_per_device", wb1, f"tp2={wb2};ratio={wb1 / wb2:.2f}")
    emit("tp.pool_per_device", pb1, f"tp2={pb2};ratio={pb1 / pb2:.2f}")
    # equal-total-memory scaling: the sharded fraction halves per device
    # (smoke shapes carry a big replicated embed/head, so the bound is loose)
    assert wb2 < wb1 and pb2 == pb1 // 2, (wb1, wb2, pb1, pb2)

    emit_json("tp", {"tp2_bit_exact": match,
                     "dispatches_tp_invariant": disp == disp2,
                     "weights_per_device_ratio": round(wb1 / wb2, 3),
                     "pool_per_device_ratio": round(pb1 / pb2, 3)})


if __name__ == "__main__":
    main()
