"""Speculative decoding vs plain paged decode (spec tentpole).

Decode pays one target-model dispatch per token (sync='host') or per
window (sync='device'); its M=1-per-lane matmuls are stuck on the
memory-bound flexible path. Speculative decoding (serving/spec.py +
``PagedBatcher(spec=...)``) converts the same token stream into rounds:
K cheap draft proposals per lane, then ONE ``paged_verify`` target
dispatch scoring all K+1 positions — an M = lanes*(K+1) matmul the
partition solver plans via its VERIFY site class. Greedy verification is
lossless, so the spec arms must be BIT-EXACT against the non-spec arms;
the win is strictly fewer target dispatches per emitted token.

Arms, for each sync in {host, device} and K in {2, 4}:
  * baseline — non-spec PagedBatcher (per-token dispatches under host
    sync, fused windows of ``WINDOW`` under device sync);
  * spec.k<K> — self-speculation (the target drafts for itself): the
    acceptance-rate upper bound, every round emits K+1 tokens per lane.
    Asserted: bit-exact outputs AND strictly fewer target dispatches per
    emitted token than the baseline, acceptance counters via ``stats()``;
  * spec.k<K>.indep — an INDEPENDENT draft model (smollm smoke config —
    two models in one serving process): still bit-exact by construction,
    acceptance reported, no dispatch assertion (a random-init draft earns
    ~zero acceptance; it demonstrates robustness, not speed).

Plus the solver's analytic account (full-size llama3-8b): the VERIFY
decision per site and ``verify_gain_us`` — one M = lanes*(K+1) dispatch vs
K+1 M = lanes dispatches each paying T_sync.

Rows: ``spec.<sync>.<arm>,us_total,...`` + ``spec.solver.<site>`` rows;
headline numbers land in ``BENCH_spec.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.configs import get_config, get_smoke_config
from repro.core.profiler import profile_analytic
from repro.core.solver import PartitionSolver
from repro.models import build_model
from repro.serving.scheduler import PagedBatcher, Request
from repro.serving.spec import SpecConfig

BLOCK_SIZE = 16
NEW_TOKENS = 21                       # 20 decode steps per request
PROMPT_SIZES = (24, 40, 17, 56)
WINDOW = 2                            # non-spec device-sync window


def _requests(cfg) -> list[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                    max_new_tokens=NEW_TOKENS)
            for i, s in enumerate(PROMPT_SIZES)]


def _run(cfg, params, **kw) -> tuple[list[Request], float, PagedBatcher]:
    max_len = max(PROMPT_SIZES) + NEW_TOKENS
    n = len(PROMPT_SIZES)
    pb = PagedBatcher(cfg, params,
                      num_blocks=1 + n * -(-max_len // BLOCK_SIZE),
                      block_size=BLOCK_SIZE,
                      max_blocks_per_seq=-(-max_len // BLOCK_SIZE),
                      decode_width=n, buckets=(32, 64),
                      cache_dtype=jnp.float32, **kw)
    reqs = _requests(cfg)
    t0 = time.perf_counter()
    pb.run(reqs)
    dt = time.perf_counter() - t0
    pb.kv.assert_drained()
    return reqs, dt, pb


def main() -> None:
    cfg = get_smoke_config("llama3-8b").with_(param_dtype="float32",
                                              compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    indep_draft = get_smoke_config("smollm-135m").with_(
        param_dtype="float32", compute_dtype="float32")
    headline = {}

    for sync in ("host", "device"):
        kw = {"sync": sync} if sync == "host" else \
             {"sync": sync, "window": WINDOW}
        reqs_b, dt_b, base = _run(cfg, params, **kw)
        bs = base.stats()
        tokens = sum(len(r.output) for r in reqs_b)
        emit(f"spec.{sync}.baseline", dt_b * 1e6,
             f"target_dispatches={bs['total_dispatches']};tokens={tokens};"
             f"disp_per_tok={bs['total_dispatches'] / tokens:.3f}")
        for k in (2, 4):
            reqs_s, dt_s, spec = _run(cfg, params, spec=SpecConfig(k=k),
                                      **kw)
            ss = spec.stats()
            match = all(b.output == s.output
                        for b, s in zip(reqs_b, reqs_s))
            emit(f"spec.{sync}.k{k}", dt_s * 1e6,
                 f"target_dispatches={ss['target_dispatches']};"
                 f"tokens={tokens};"
                 f"disp_per_tok={ss['target_dispatches'] / tokens:.3f};"
                 f"verify={ss['verify_dispatches']};"
                 f"accept_rate={ss['acceptance_rate']:.2f};match={match}")
            assert match, (f"sync={sync} k={k}: speculative greedy outputs "
                           "diverged from the non-spec arm")
            assert ss["target_dispatches"] < bs["total_dispatches"], (
                f"sync={sync} k={k}: spec arm issued "
                f"{ss['target_dispatches']} target dispatches vs "
                f"{bs['total_dispatches']} baseline; expected strictly "
                "fewer per emitted token")
            assert ss["acceptance_rate"] > 0.0 and ss["spec_rounds"] > 0
            headline[f"{sync}.k{k}"] = {
                "target_dispatches": ss["target_dispatches"],
                "baseline_dispatches": bs["total_dispatches"],
                "tokens": tokens,
                "acceptance_rate": round(ss["acceptance_rate"], 3),
            }
        # independent draft model: two models in one serving process —
        # correctness is draft-agnostic, acceptance is reported not asserted
        reqs_i, dt_i, indep = _run(
            cfg, params, spec=SpecConfig(k=4, draft=indep_draft), **kw)
        si = indep.stats()
        match = all(b.output == s.output for b, s in zip(reqs_b, reqs_i))
        emit(f"spec.{sync}.k4.indep", dt_i * 1e6,
             f"draft={si['draft_model']};"
             f"target_dispatches={si['target_dispatches']};"
             f"accept_rate={si['acceptance_rate']:.2f};match={match}")
        assert match, (f"sync={sync}: independent-draft outputs diverged "
                       "from the non-spec arm")

    # the solver's analytic account (full-size model): VERIFY site class
    full = get_config("llama3-8b")
    solver = PartitionSolver(profile_analytic(full), sync_mode="host")
    for site in ("wq", "w_gate", "head"):
        dec = solver.solve_verify(site, 4, lanes=8)
        gain = solver.verify_gain_us(site, 4, lanes=8)
        emit(f"spec.solver.{site}", dec.t_us,
             f"strategy={dec.strategy};ratio={dec.ratio};"
             f"gain_vs_serial_us={gain:.1f}")
    emit_json("spec", headline)


if __name__ == "__main__":
    main()
