"""§Roofline / §Dry-run report: reads the dry-run artifacts and emits one row
per (arch x shape) with the three roofline terms + dominant bottleneck."""
from __future__ import annotations

from repro.roofline.analysis import analyze_all

from .common import emit, emit_json


def main() -> None:
    for c in analyze_all():
        if c.skipped:
            emit(f"roofline/{c.arch}/{c.shape}", 0.0, f"SKIP:{c.reason[:60]}")
        elif not c.ok:
            emit(f"roofline/{c.arch}/{c.shape}", 0.0, f"FAIL:{c.reason[:60]}")
        else:
            emit(f"roofline/{c.arch}/{c.shape}", c.bound_time_s * 1e6,
                 f"dom={c.dominant},comp_ms={c.compute_s*1e3:.2f},"
                 f"mem_ms={c.memory_s*1e3:.2f},coll_ms={c.collective_s*1e3:.2f},"
                 f"useful={c.useful_ratio:.2f},roofline_frac={c.roofline_fraction:.2f}")

    emit_json("roofline")


if __name__ == "__main__":
    main()
