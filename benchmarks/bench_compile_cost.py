"""Paper Fig 8 — 'NPU graph generation time' analogue: XLA trace+compile
latency vs tensor shape. This is the cost Online-prepare pays per novel
sequence length and the reason bucketed static shapes + ragged-remainder
offload exist (activation-centric partitioning).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import emit, emit_json


def main() -> None:
    for m in (64, 128, 256, 512, 1024):
        def f(x, w):
            for _ in range(4):           # a 4-matmul "operator graph"
                x = jnp.tanh(x @ w)
            return x
        x = jax.ShapeDtypeStruct((m, 1024), jnp.float32)
        w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        t0 = time.perf_counter()
        jax.jit(f).lower(x, w).compile()  # repolint: disable=jit-hygiene -- re-jitting per shape is the EXPERIMENT: this bench measures the per-novel-shape compile cost (Fig 8)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"fig8_compile_cost/M={m}", dt, "per-novel-shape")

    emit_json("compile_cost")


if __name__ == "__main__":
    main()
