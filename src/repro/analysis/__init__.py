"""repolint — repo-custom static analysis over Python ASTs.

The serving stack's core invariants (donated-buffer discipline, zero
wall-clock outside the injectable Clock, host-sync-free hot loops,
collision-free stats()/metrics schemas) were previously guarded by
convention, ad-hoc CI greps, and runtime assertions that only fire when a
test happens to exercise the bad path. This package proves them statically
over every file, on every commit, before anything runs:

  * :mod:`repro.analysis.core`   — findings, per-line pragmas, baseline,
    file walker, and the :func:`run_repolint` driver.
  * :mod:`repro.analysis.rules`  — the AST rules: ``use-after-donate``,
    ``determinism``, ``jit-hygiene``, ``host-sync``.
  * :mod:`repro.analysis.schema` — the project-level ``schema-contract``
    rule cross-checking stats() keys, tracer counter names,
    ``STATS_COUNTER_KEYS`` and docs/observability.md.

Entry point: ``scripts/repolint.py`` (CI runs ``--check``); see
docs/static-analysis.md for the rule catalog and pragma/baseline workflow.
"""
from .core import (Baseline, Finding, Report, RULE_NAMES, run_repolint,
                   walk_tree)

__all__ = ["Baseline", "Finding", "Report", "RULE_NAMES", "run_repolint",
           "walk_tree"]
