"""schema-contract: statically cross-check the observability schema.

Four artifacts describe the same schema and drift independently:

  1. the ``stats()`` dict literals in both batchers (+ the prefix-cache and
     ingress sub-dicts merged into them),
  2. the literal ``tracer.count("...")`` / ``tracer.gauge("...")`` call
     sites scattered through serving/,
  3. ``STATS_COUNTER_KEYS`` / ``STATS_GAUGE_KEYS`` in serving/trace.py
     (what ``counter_reconciliation()`` reconciles), and
  4. the counter/gauge bullets and dispatch-span table in
     docs/observability.md.

The runtime contract (``counter_reconciliation``) only catches a drift when
a test exercises the drifted counter; this rule proves all four artifacts
agree by construction, for every key, on every commit. It is a *project*
rule: it reads configured files rather than firing per module, so the
fixture tests can point it at a synthetic tree.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

from .core import Finding, Module, dotted_name, register_rule


@dataclass(frozen=True)
class StatsSource:
    """One function whose ast.Dict literals are stats key groups."""
    relpath: str
    cls: str          # "" for module-level functions
    func: str
    label: str
    merged: bool      # True if its groups are .update()-merged into the
                      # paged stats dict (must be collision-free); the dense
                      # batcher intentionally mirrors paged keys -> False


@dataclass
class SchemaConfig:
    trace_relpath: str = "src/repro/serving/trace.py"
    docs_relpath: str = "docs/observability.md"
    #: counter names legal at call sites but deliberately NOT in stats()
    #: (trace.py's internal per-kind dispatch counter)
    extra_counters: tuple = ("dispatches",)
    sources: tuple = (
        StatsSource("src/repro/serving/scheduler.py", "ContinuousBatcher",
                    "stats", "dense", merged=False),
        StatsSource("src/repro/serving/scheduler.py", "PagedBatcher",
                    "stats", "paged", merged=True),
        StatsSource("src/repro/serving/paged_cache.py", "PagedKVCache",
                    "prefix_stats", "prefix", merged=True),
        StatsSource("src/repro/serving/ingress.py", "AsyncServer",
                    "stats", "ingress", merged=True),
    )
    #: stats() keys that are snapshots/config, not reconciled counters —
    #: they may appear in stats groups without a tracer emission
    #: (documented in docs/observability.md prose, not the counter bullet)
    snapshot_keys: tuple = ("tp", "spec_k", "draft_model", "acceptance_rate",
                            "total_dispatches", "target_dispatches")

DEFAULT_CONFIG = SchemaConfig()


# ----------------------------------------------------------- AST extraction

def _find_function(tree: ast.AST, cls: str, func: str):
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if cls and isinstance(node, ast.ClassDef) and node.name == cls:
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub.name == func:
                    return sub
        elif not cls and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == func:
            return node
    return None


def _dict_groups(fn) -> list[tuple[int, set]]:
    """Every all-constant-string-keyed dict literal in ``fn`` as
    (lineno, keyset) — one group per literal, so PagedBatcher.stats yields
    its base dict and its spec ``update({...})`` dict separately."""
    groups = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict) and node.keys:
            keys = set()
            ok = True
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
                else:
                    ok = False
            if ok:
                groups.append((node.lineno, keys))
    return groups


def _module_tuple(tree: ast.AST, name: str) -> tuple | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            try:
                v = ast.literal_eval(node.value)
            except ValueError:
                return None
            return tuple(v), node.lineno
    return None


def _tracer_emissions(modules: list[Module]):
    """Literal tracer call sites across the tree.

    Returns (counts, gauges, kinds): each a dict name -> first (mod, line).
    Receivers must END in ``tracer`` (``self.tracer``, a bare ``tracer``),
    which deliberately excludes trace.py's internal ``self.metrics.count``.
    Dispatch kinds come from ``.dispatch("lit")``, ``._dispatch_span("lit")``
    and ``.span("lit", ..., cat="sync")`` (core/sync.py's fused_window)."""
    counts: dict = {}
    gauges: dict = {}
    kinds: dict = {}

    def first_str(call: ast.Call):
        if call.args and isinstance(call.args[0], ast.Constant) \
                and isinstance(call.args[0].value, str):
            return call.args[0].value
        return None

    for mod in modules:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            meth = node.func.attr
            recv = dotted_name(node.func.value) or ""
            recv_is_tracer = recv == "tracer" or recv.endswith(".tracer")
            lit = first_str(node)
            if lit is None:
                continue
            if meth in ("count", "gauge") and recv_is_tracer:
                (counts if meth == "count" else gauges).setdefault(
                    lit, (mod, node.lineno))
            elif meth in ("dispatch", "_dispatch_span") and (
                    recv_is_tracer or meth == "_dispatch_span"):
                kinds.setdefault(lit, (mod, node.lineno))
            elif meth == "span" and recv_is_tracer:
                for kw in node.keywords:
                    if kw.arg == "cat" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value == "sync":
                        kinds.setdefault(lit, (mod, node.lineno))
    return counts, gauges, kinds


# ----------------------------------------------------------- docs extraction

_BACKTICK_RE = re.compile(r"`([^`]+)`")


def _doc_bullet_tokens(section: str, bullet_prefix: str) -> set:
    """Identifier tokens inside backticks in the bullet starting with
    ``bullet_prefix`` (tokens are cut at '{' for labeled families like
    ``dispatches{kind=...}``; suffix tokens like ``_total`` are skipped)."""
    m = re.search(re.escape(bullet_prefix) + r".*?(?=\n- |\n\n|\Z)",
                  section, re.S)
    if m is None:
        return set()
    out = set()
    for tok in _BACKTICK_RE.findall(m.group(0)):
        tok = tok.split("{")[0].strip()
        if tok and not tok.startswith("_") \
                and re.fullmatch(r"[A-Za-z][A-Za-z0-9_]*", tok):
            out.add(tok)
    return out


def _doc_sections(text: str) -> dict:
    """'## Heading' -> section body text."""
    out = {}
    parts = re.split(r"^## +(.+)$", text, flags=re.M)
    for i in range(1, len(parts) - 1, 2):
        out[parts[i].strip()] = parts[i + 1]
    return out


def _doc_dispatch_names(text: str) -> tuple[set, int]:
    """First-column backtick names from the dispatch-span table (combined
    cells like ``mixed_step`` / ``mixed_window`` split into both)."""
    m = re.search(r"\*\*Dispatch spans\*\*(.*?)(?=\n\*\*|\n## |\Z)",
                  text, re.S)
    if m is None:
        return set(), 0
    line0 = text[:m.start()].count("\n") + 1
    names = set()
    for row in m.group(1).splitlines():
        row = row.strip()
        if not row.startswith("|") or row.startswith("|--") \
                or row.startswith("| name") or row.startswith("|---"):
            continue
        first_cell = row.split("|")[1]
        names.update(_BACKTICK_RE.findall(first_cell))
    return names, line0


# ------------------------------------------------------------------- rule --

@register_rule("schema-contract", kind="project")
def check_schema_contract(root: Path, modules: list[Module],
                          config: SchemaConfig = DEFAULT_CONFIG) -> list:
    findings: list = []
    by_path = {m.relpath: m for m in modules}

    def fail(relpath: str, line: int, message: str, snippet: str = ""):
        findings.append(Finding(rule="schema-contract", path=relpath,
                                line=line, message=message, snippet=snippet))

    # --- 3. the trace.py registry -----------------------------------------
    trace_mod = by_path.get(config.trace_relpath)
    if trace_mod is None:
        fail(config.trace_relpath, 1,
             "trace module not found — cannot check the schema contract")
        return findings
    ck = _module_tuple(trace_mod.tree, "STATS_COUNTER_KEYS")
    gk = _module_tuple(trace_mod.tree, "STATS_GAUGE_KEYS")
    if ck is None or gk is None:
        fail(config.trace_relpath, 1,
             "STATS_COUNTER_KEYS / STATS_GAUGE_KEYS tuples not found")
        return findings
    counter_keys, ck_line = set(ck[0]), ck[1]
    gauge_keys, gk_line = set(gk[0]), gk[1]

    # --- 1. stats() dict groups -------------------------------------------
    groups: list[tuple[StatsSource, int, set]] = []
    for src in config.sources:
        mod = by_path.get(src.relpath)
        fn = _find_function(mod.tree, src.cls, src.func) if mod else None
        if fn is None:
            fail(src.relpath, 1,
                 f"stats source {src.cls or '<module>'}.{src.func} not "
                 f"found — update analysis/schema.py's SchemaConfig")
            continue
        for line, keys in _dict_groups(fn):
            groups.append((src, line, keys))
    stats_keys = set().union(*(g[2] for g in groups)) if groups else set()

    # every reconciled key must be produced by some stats() group
    for key in sorted((counter_keys | gauge_keys) - stats_keys):
        fail(config.trace_relpath,
             ck_line if key in counter_keys else gk_line,
             f"STATS key {key!r} is reconciled by counter_reconciliation() "
             f"but no batcher/pool stats() dict produces it")

    # merged groups must be collision-free (they .update() into one dict)
    merged = [(s, ln, keys) for s, ln, keys in groups if s.merged]
    for i, (sa, la, ka) in enumerate(merged):
        for sb, lb, kb in merged[i + 1:]:
            if sa.relpath == sb.relpath and la == lb:
                continue
            for key in sorted(ka & kb):
                fail(sb.relpath, lb,
                     f"stats key {key!r} in {sb.label} group collides with "
                     f"{sa.label} group ({sa.relpath}:{la}) — these dicts "
                     f"merge into one stats() snapshot")

    # --- 2. tracer emission sites -----------------------------------------
    counts, gauges, kinds = _tracer_emissions(modules)
    legal_counts = counter_keys | set(config.extra_counters)
    for name, (mod, line) in sorted(counts.items()):
        if name not in legal_counts:
            fail(mod.relpath, line,
                 f"tracer.count({name!r}) is not in STATS_COUNTER_KEYS — "
                 f"counter_reconciliation() will never check it",
                 mod.line_at(line))
    for name, (mod, line) in sorted(gauges.items()):
        if name not in gauge_keys:
            fail(mod.relpath, line,
                 f"tracer.gauge({name!r}) is not in STATS_GAUGE_KEYS",
                 mod.line_at(line))
    for key in sorted(counter_keys - set(counts)):
        fail(config.trace_relpath, ck_line,
             f"STATS counter {key!r} has no literal tracer.count() site — "
             f"the metrics ledger can never move for it")
    for key in sorted(gauge_keys - set(gauges)):
        fail(config.trace_relpath, gk_line,
             f"STATS gauge {key!r} has no literal tracer.gauge() site")

    # --- 4. docs/observability.md -----------------------------------------
    docs_path = root / config.docs_relpath
    if not docs_path.exists():
        fail(config.docs_relpath, 1, "observability doc missing")
        return findings
    text = docs_path.read_text()
    sections = _doc_sections(text)
    metrics = sections.get("Metrics exposition", "")
    doc_counters = _doc_bullet_tokens(metrics, "- counters")
    doc_gauges = _doc_bullet_tokens(metrics, "- gauges")
    want_counters = counter_keys | set(config.extra_counters)
    for key in sorted(want_counters - doc_counters):
        fail(config.docs_relpath, 1,
             f"counter {key!r} missing from the docs counters bullet")
    for key in sorted(doc_counters - want_counters):
        fail(config.docs_relpath, 1,
             f"docs list counter {key!r} which the code does not emit")
    for key in sorted(gauge_keys - doc_gauges):
        fail(config.docs_relpath, 1,
             f"gauge {key!r} missing from the docs gauges bullet")
    for key in sorted(doc_gauges - gauge_keys):
        fail(config.docs_relpath, 1,
             f"docs list gauge {key!r} which the code does not emit")

    doc_kinds, table_line = _doc_dispatch_names(text)
    code_kinds = set(kinds)
    for k in sorted(code_kinds - doc_kinds):
        mod, line = kinds[k]
        fail(mod.relpath, line,
             f"dispatch span kind {k!r} is emitted but missing from the "
             f"docs dispatch-span table", mod.line_at(line))
    for k in sorted(doc_kinds - code_kinds):
        fail(config.docs_relpath, table_line,
             f"docs dispatch-span table names {k!r} but no code emits it")
    return findings
