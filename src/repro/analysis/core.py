"""repolint infrastructure: findings, pragmas, baseline, walker, driver.

Design notes
------------
* A :class:`Finding` is anchored to a (path, line) but its baseline
  *fingerprint* deliberately excludes the line number — ``path::rule::
  stripped-source-line`` — so unrelated edits above a grandfathered finding
  don't churn the committed baseline.
* Suppression is per-line: ``# repolint: disable=<rule>[,<rule>...] --
  <reason>`` on the flagged line. The reason is mandatory (a bare pragma is
  itself a finding) and a pragma that suppresses nothing is flagged too, so
  stale suppressions can't linger — the same philosophy as
  scripts/check_skips.py's stale-allowlist check.
* The baseline file (``.repolint-baseline.json`` at the repo root) holds a
  multiset of fingerprints for grandfathered findings. ``--check`` fails on
  findings missing from the baseline AND on baseline entries that no longer
  fire. The committed baseline is empty: every pre-existing finding was
  fixed or pragma'd with a reason.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable, Optional

# src/repro/analysis/core.py -> repo root
REPO_ROOT = Path(__file__).resolve().parents[3]

# directories repolint walks, relative to the repo root
DEFAULT_ROOTS = ("src", "tests", "benchmarks", "scripts")

BASELINE_NAME = ".repolint-baseline.json"

_PRAGMA_RE = re.compile(
    r"#\s*repolint:\s*disable=([A-Za-z0-9_,\- ]+?)\s*(?:--\s*(.*?)\s*)?$")


# ---------------------------------------------------------------- findings --

@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str          # repo-relative posix path
    line: int          # 1-based
    message: str
    snippet: str = ""  # stripped source line (baseline anchor)

    @property
    def fingerprint(self) -> str:
        return f"{self.path}::{self.rule}::{self.snippet}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class Pragma:
    line: int
    rules: tuple
    reason: str
    used: bool = False


def parse_pragmas(source) -> dict[int, Pragma]:
    """Per-line ``# repolint: disable=...`` suppressions (1-based lines).

    Tokenize-based: only actual COMMENT tokens count, so pragma-shaped text
    inside string literals (fixture snippets in tests) is ignored. Accepts
    the module source string, or a list of lines for convenience."""
    if not isinstance(source, str):
        source = "\n".join(source) + "\n"
    out: dict[int, Pragma] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",")
                              if r.strip())
                line = tok.start[0]
                out[line] = Pragma(line=line, rules=rules,
                                   reason=(m.group(2) or "").strip())
    except (tokenize.TokenError, IndentationError):
        pass   # unparseable files never reach the rules either
    return out


# ----------------------------------------------------------------- modules --

@dataclass
class Module:
    """One parsed source file handed to every per-module rule."""
    path: Path
    relpath: str                 # posix, repo-relative
    source: str
    lines: list[str]
    tree: ast.AST
    aliases: dict = field(default_factory=dict)

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 0))
        return Finding(rule=rule, path=self.relpath, line=line,
                       message=message, snippet=self.line_at(line))


def build_alias_map(tree: ast.AST) -> dict:
    """Local name -> canonical dotted prefix, from the module's imports
    (``import numpy as np`` -> np: numpy; ``from time import sleep as zz``
    -> zz: time.sleep). Resolution is textual — no imports are executed."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve(name: Optional[str], aliases: dict) -> Optional[str]:
    """Canonicalize a dotted name through the module's import aliases."""
    if name is None:
        return None
    head, _, rest = name.partition(".")
    base = aliases.get(head)
    if base is None:
        return name
    return f"{base}.{rest}" if rest else base


def load_module(path: Path, root: Path) -> Optional[Module]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    rel = path.relative_to(root).as_posix()
    return Module(path=path, relpath=rel, source=source,
                  lines=source.splitlines(), tree=tree,
                  aliases=build_alias_map(tree))


def walk_tree(root: Path | str | None = None,
              roots: Iterable[str] = DEFAULT_ROOTS) -> list[Path]:
    root = Path(root) if root is not None else REPO_ROOT
    files: list[Path] = []
    for sub in roots:
        base = root / sub
        if base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return files


# ---------------------------------------------------------------- baseline --

class Baseline:
    """Multiset of grandfathered finding fingerprints."""

    def __init__(self, counts: Counter | None = None):
        self.counts: Counter = counts or Counter()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        return cls(Counter(data.get("fingerprints", [])))

    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        return cls(Counter(f.fingerprint for f in findings))

    def save(self, path: Path) -> None:
        fps = sorted(self.counts.elements())
        path.write_text(json.dumps({"version": 1, "fingerprints": fps},
                                   indent=1) + "\n")

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[str]]:
        """(new findings not covered by the baseline, stale baseline
        entries that no longer fire). Duplicates are consumed count-wise."""
        budget = Counter(self.counts)
        new = []
        for f in findings:
            if budget[f.fingerprint] > 0:
                budget[f.fingerprint] -= 1
            else:
                new.append(f)
        stale = sorted(budget.elements())
        return new, stale


# ------------------------------------------------------------------ driver --

#: registry: rule name -> (kind, fn). Per-module rules get one Module;
#: project rules get (root, list[Module]) and may read non-Python files.
_RULES: dict[str, tuple[str, Callable]] = {}


def register_rule(name: str, kind: str = "module"):
    assert kind in ("module", "project"), kind

    def deco(fn):
        _RULES[name] = (kind, fn)
        return fn
    return deco


def rule_registry() -> dict:
    _ensure_rules_loaded()
    return dict(_RULES)


_RULES_LOADED = False


def _ensure_rules_loaded():
    # deferred: rules.py/schema.py import core for the registry decorator
    global _RULES_LOADED
    if not _RULES_LOADED:
        from . import rules, schema   # noqa: F401  (registration side effect)
        _RULES_LOADED = True


RULE_NAMES = ("use-after-donate", "determinism", "jit-hygiene", "host-sync",
              "schema-contract")
#: meta-rule for pragma hygiene (bare / unknown / unused pragmas);
#: emitted by the driver itself, not suppressible.
PRAGMA_RULE = "pragma"


@dataclass
class Report:
    findings: list[Finding]          # after pragma suppression (incl. meta)
    new: list[Finding]               # findings not covered by the baseline
    stale: list[str]                 # baseline entries that no longer fire
    suppressed: int
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale

    def summary(self) -> str:
        per_rule = Counter(f.rule for f in self.findings)
        rules = ", ".join(f"{r}={n}" for r, n in sorted(per_rule.items())) \
            or "none"
        return (f"[repolint] {self.n_files} files, "
                f"{len(self.findings)} findings ({rules}), "
                f"{self.suppressed} suppressed by pragma, "
                f"{len(self.new)} new vs baseline, "
                f"{len(self.stale)} stale baseline entries")


def run_repolint(root: Path | str | None = None, *,
                 rules: Iterable[str] | None = None,
                 roots: Iterable[str] = DEFAULT_ROOTS,
                 baseline: Baseline | str | Path | None = None) -> Report:
    """Walk ``roots`` under ``root``, run ``rules`` (default: all), apply
    per-line pragmas, and diff raw findings against the baseline."""
    _ensure_rules_loaded()
    root = Path(root) if root is not None else REPO_ROOT
    selected = tuple(rules) if rules is not None else tuple(_RULES)
    unknown = [r for r in selected if r not in _RULES]
    if unknown:
        raise ValueError(f"unknown rule(s): {unknown}; "
                         f"known: {sorted(_RULES)}")
    if baseline is None:
        baseline = Baseline.load(root / BASELINE_NAME)
    elif not isinstance(baseline, Baseline):
        baseline = Baseline.load(Path(baseline))

    modules = [m for m in (load_module(p, root)
                           for p in walk_tree(root, roots)) if m]
    raw: list[Finding] = []
    for name in selected:
        kind, fn = _RULES[name]
        if kind == "module":
            for mod in modules:
                raw.extend(fn(mod))
        else:
            raw.extend(fn(root, modules))

    findings, suppressed = [], 0
    metas: list[Finding] = []
    for mod in modules:
        pragmas = parse_pragmas(mod.source)
        for p in pragmas.values():
            for r in p.rules:
                if r not in _RULES:
                    metas.append(mod.finding(
                        PRAGMA_RULE, p.line,
                        f"pragma names unknown rule {r!r}"))
            if not p.reason:
                metas.append(mod.finding(
                    PRAGMA_RULE, p.line,
                    "pragma has no reason — write "
                    "'# repolint: disable=<rule> -- <why>'"))
        mod_findings = [f for f in raw if f.path == mod.relpath]
        for f in mod_findings:
            p = pragmas.get(f.line)
            if p is not None and f.rule in p.rules:
                p.used = True
                suppressed += 1
            else:
                findings.append(f)
        for p in pragmas.values():
            if not p.used and all(r in _RULES for r in p.rules):
                metas.append(mod.finding(
                    PRAGMA_RULE, p.line,
                    f"unused pragma (suppresses no "
                    f"{'/'.join(p.rules)} finding) — remove it"))
    # project-rule findings on non-module files (e.g. docs/*.md) pass through
    seen_paths = {m.relpath for m in modules}
    findings.extend(f for f in raw if f.path not in seen_paths)
    findings.extend(metas)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    new, stale = baseline.split(findings)
    return Report(findings=findings, new=new, stale=stale,
                  suppressed=suppressed, n_files=len(modules))
