"""Per-module AST rules: use-after-donate, determinism, jit-hygiene,
host-sync.

Every rule is intentionally repo-custom: allowlists and name patterns
below encode THIS codebase's conventions (the injectable Clock in
serving/telemetry.py, the sanctioned sync sites in core/sync.py, the
pool-carrying jit entry points of the paged serving stack). Rules are
conservative by construction — they resolve names through the module's
import aliases and track only what can be decided locally, so a clean
report means the discipline provably holds at every site the rule can
see, and a finding is near-certainly real.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Optional

from .core import Module, dotted_name, register_rule, resolve

# =========================================================== determinism ====
#
# Ban ambient wall-clock / RNG outside the injectable Clock. The tier-1
# determinism contract (telemetry.FakeClock, seeded generators) only holds
# if nothing reads the real clock or global RNG state behind its back.
# Allowlist: serving/telemetry.py IS the clock (time.monotonic), and
# benchmarks measure wall time by definition (perf_counter only — never
# sleep). Everything else is a finding: fix (inject a Clock) or pragma
# with a reason.

_DETERMINISM_BANNED = {
    "time.time": (),
    "time.sleep": (),
    "time.monotonic": ("src/repro/serving/telemetry.py",),
    "time.monotonic_ns": ("src/repro/serving/telemetry.py",),
    "time.perf_counter": ("benchmarks/",),
    "time.perf_counter_ns": ("benchmarks/",),
    "datetime.datetime.now": (),
    "datetime.datetime.utcnow": (),
    "datetime.datetime.today": (),
    "datetime.date.today": (),
}
# global-state RNGs: the stdlib random module and numpy's legacy module-
# level API. Seeded generator objects (np.random.default_rng(seed),
# jax.random.PRNGKey) are the sanctioned sources and are NOT flagged.
_LEGACY_NP_RANDOM = {
    "seed", "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "uniform", "normal", "standard_normal",
}


def _allowed(relpath: str, prefixes: tuple) -> bool:
    return any(relpath == p or relpath.startswith(p) for p in prefixes)


@register_rule("determinism")
def check_determinism(mod: Module) -> list:
    findings = []

    def flag(node, name):
        findings.append(mod.finding(
            "determinism", node,
            f"{name} is banned outside the injectable Clock "
            f"(serving/telemetry.py) — thread a Clock through, or pragma "
            f"with a reason"))

    for node in ast.walk(mod.tree):
        name = None
        if isinstance(node, (ast.Attribute, ast.Name)):
            name = resolve(dotted_name(node), mod.aliases)
        if name is None:
            continue
        if name in _DETERMINISM_BANNED:
            if not _allowed(mod.relpath, _DETERMINISM_BANNED[name]):
                flag(node, name)
        elif name.startswith("random.") and name.count(".") == 1:
            flag(node, name)
        elif name.startswith("numpy.random.") \
                and name.rsplit(".", 1)[1] in _LEGACY_NP_RANDOM:
            flag(node, name + " (global-state RNG; use "
                 "np.random.default_rng(seed))")
    # dedupe: a.b inside a.b.c walks both nodes; keep the outermost match
    seen, out = set(), []
    for f in findings:
        key = (f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


# ======================================================= jit construction ===

_JIT_NAMES = {"jax.jit"}
_SHARD_MAP_NAMES = {
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.shard_map",
    "repro.distributed.compat.shard_map",
}
# functions whose first positional arg threads a KV pool / dense cache that
# the serving stack re-binds from the jitted call's return: jitting them
# without donation doubles peak pool memory on real backends
_POOL_CARRYING = {
    "paged_prefill", "paged_decode_step", "paged_verify", "mixed_step",
    "decode_step", "prefill", "prefill_slot", "_cow_copy", "train_step",
}
# functions re-jitted per call churn the trace cache: anything named like a
# per-step/per-tick/per-request entry point must not CONSTRUCT a jit.
# Builder/factory functions (build_*, make_*) construct the jit ONCE by
# design, and a test jitting locally is harmless — both are exempt.
_HOT_FN_RE = re.compile(r"(^_?(step|tick)$)|(_step$)|(_tick$)|(^generate)")
_HOT_FN_EXEMPT = ("build_", "make_", "create_", "test_",
                  "_build_", "_make_", "_create_")


def _is_hot_fn(name: str) -> bool:
    return bool(_HOT_FN_RE.search(name)) \
        and not name.startswith(_HOT_FN_EXEMPT)
# the donation sub-check applies to library code only — a test or bench
# jitting a pool-carrying fn once, without donation, is harmless
_DONATION_CHECK_PREFIXES = ("src/",)


def _jit_callee_name(call: ast.Call, aliases: dict) -> Optional[str]:
    """Best-effort name of what's being jitted: Name, last attr of an
    Attribute, a constant-string Subscript key (``paged_fns["paged_verify"]``),
    or a ``functools.partial(...)``'s first argument, recursively."""
    if not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Call) \
            and resolve(dotted_name(arg.func), aliases) == "functools.partial":
        return _jit_callee_name(arg, aliases)
    if isinstance(arg, ast.Name):
        return arg.id
    if isinstance(arg, ast.Attribute):
        return arg.attr
    if isinstance(arg, ast.Subscript):
        sl = arg.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            return sl.value
    return None


def _has_donation(call: ast.Call) -> bool:
    return any(kw.arg in ("donate_argnums", "donate_argnames")
               for kw in call.keywords)


@register_rule("jit-hygiene")
def check_jit_hygiene(mod: Module) -> list:
    findings = []

    class V(ast.NodeVisitor):
        def __init__(self):
            self.loop_depth = 0
            self.fn_stack: list[str] = []

        def visit_For(self, node):
            self._loop(node)

        def visit_While(self, node):
            self._loop(node)

        def _loop(self, node):
            self.loop_depth += 1
            self.generic_visit(node)
            self.loop_depth -= 1

        def visit_FunctionDef(self, node):
            self.fn_stack.append(node.name)
            # decorators evaluate at def time, outside the body
            saved, self.loop_depth = self.loop_depth, self.loop_depth
            for d in node.decorator_list:
                self.visit(d)
            body_saved = self.loop_depth
            for stmt in node.body:
                self.visit(stmt)
            self.loop_depth = saved if body_saved == saved else body_saved
            self.fn_stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node):
            name = resolve(dotted_name(node.func), mod.aliases)
            is_jit = name in _JIT_NAMES
            is_smap = name in _SHARD_MAP_NAMES
            if is_jit or is_smap:
                what = "jax.jit" if is_jit else "shard_map"
                if self.loop_depth > 0:
                    findings.append(mod.finding(
                        "jit-hygiene", node,
                        f"{what} constructed inside a loop — every "
                        f"iteration builds a fresh wrapper with its own "
                        f"trace cache (retrace churn); hoist it out"))
                elif self.fn_stack and _is_hot_fn(self.fn_stack[-1]):
                    findings.append(mod.finding(
                        "jit-hygiene", node,
                        f"{what} constructed inside per-call function "
                        f"{self.fn_stack[-1]!r} — re-jitting on every "
                        f"call retraces; cache the jitted callable"))
                if is_jit and not _has_donation(node) \
                        and _allowed(mod.relpath, _DONATION_CHECK_PREFIXES):
                    callee = _jit_callee_name(node, mod.aliases)
                    if callee in _POOL_CARRYING:
                        findings.append(mod.finding(
                            "jit-hygiene", node,
                            f"jax.jit({callee}) without donate_argnums — "
                            f"pool/cache-carrying functions must donate "
                            f"their buffer or peak memory doubles"))
            self.generic_visit(node)

    V().visit(mod.tree)
    return findings


# =========================================================== donated jits ===
#
# Shared collection used by use-after-donate and host-sync: which local
# names are jax.jit-wrapped, and which argument positions they donate.

@dataclass
class JitBindings:
    #: binding name ("step", "self._decode", "_cow_copy") -> donated argnums
    donated: dict = field(default_factory=dict)
    #: names of local FunctionDefs that end up inside a jit (traced bodies)
    traced_fns: dict = field(default_factory=dict)  # name -> static argnames


def _literal_argnums(call: ast.Call) -> tuple:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            try:
                v = ast.literal_eval(kw.value)
            except ValueError:
                return ()
            return tuple(v) if isinstance(v, (tuple, list)) else (int(v),)
    return ()


def _literal_static_argnames(call: ast.Call) -> tuple:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            try:
                v = ast.literal_eval(kw.value)
            except ValueError:
                return ()
            return tuple(v) if isinstance(v, (tuple, list)) else (str(v),)
    return ()


def _as_jit_call(node: ast.AST, aliases: dict) -> Optional[ast.Call]:
    """The jax.jit(...) Call behind ``jax.jit(...)`` or
    ``partial(jax.jit, ...)`` (decorator form), else None."""
    if not isinstance(node, ast.Call):
        return None
    name = resolve(dotted_name(node.func), aliases)
    if name in _JIT_NAMES:
        return node
    if name == "functools.partial" and node.args:
        if resolve(dotted_name(node.args[0]), aliases) in _JIT_NAMES:
            return node
    return None


def collect_jit_bindings(mod: Module) -> JitBindings:
    jb = JitBindings()

    def record_fn_target(call: ast.Call):
        """If the jitted thing is a local function name (possibly through
        partial), remember its body is traced."""
        name = _jit_callee_name(call, mod.aliases)
        if name:
            jb.traced_fns.setdefault(name, _literal_static_argnames(call))

    for node in ast.walk(mod.tree):
        # decorated defs: @jax.jit (bare), @jax.jit(...) or
        # @partial(jax.jit, donate_argnums=...)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if resolve(dotted_name(dec), mod.aliases) in _JIT_NAMES:
                    jb.traced_fns.setdefault(node.name, ())
                    continue
                call = _as_jit_call(dec, mod.aliases)
                if call is not None:
                    jb.traced_fns.setdefault(
                        node.name, _literal_static_argnames(call))
                    nums = _literal_argnums(call)
                    if nums:
                        jb.donated[node.name] = nums
        # assignments: <target> = jax.jit(fn, donate_argnums=...)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            call = _as_jit_call(node.value, mod.aliases)
            if call is None or resolve(dotted_name(call.func),
                                       mod.aliases) not in _JIT_NAMES:
                continue
            record_fn_target(call)
            target = dotted_name(node.targets[0])
            if target is None:
                continue
            nums = _literal_argnums(call)
            if nums:
                jb.donated[target] = nums
    return jb


# ======================================================== use-after-donate ==
#
# A donated buffer is dead the moment the jitted call is issued: XLA may
# alias its memory for the output. The serving discipline is rebind-in-the-
# same-statement (``logits, self.kv.pool = self._decode(..., self.kv.pool)``).
# This rule walks each function linearly, marks donated argument names dead
# at the call, clears them on (re)store, and flags any read in between.
# CPU runs mask these bugs (donation is a no-op there) — which is exactly
# why a static rule, not a test, has to hold the line.

def _terminates(stmts: list) -> bool:
    """True if the block's last statement unconditionally leaves it."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


class _DonateWalker:
    def __init__(self, mod: Module, bindings: dict, findings: list):
        self.mod = mod
        self.bindings = bindings    # callable name -> donated argnums
        self.findings = findings
        self.dead: dict[str, tuple] = {}   # name -> (callee, line)

    # ------------------------------------------------------------- events --
    def read(self, name: str, node):
        for dead_name, (callee, line) in self.dead.items():
            if name == dead_name or name.startswith(dead_name + "."):
                self.findings.append(self.mod.finding(
                    "use-after-donate", node,
                    f"{name} is read after being donated to {callee}() at "
                    f"line {line} — the buffer may already be aliased; "
                    f"rebind it from the call's return first"))
                return

    def store(self, name: str):
        for dead_name in list(self.dead):
            if dead_name == name or dead_name.startswith(name + "."):
                del self.dead[dead_name]

    # -------------------------------------------------------- expressions --
    def eval_expr(self, node):
        """Process reads and donating calls in evaluation-ish order."""
        if node is None:
            return
        # only the outermost chain matters; inner Attribute/Name nodes
        # repeat a prefix of the same chain and would double-report
        inner: set = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute):
                v = sub.value
                while isinstance(v, ast.Attribute):
                    inner.add(id(v))
                    v = v.value
                if isinstance(v, ast.Name):
                    inner.add(id(v))
        for sub in ast.walk(node):
            if isinstance(sub, (ast.Name, ast.Attribute)) \
                    and id(sub) not in inner \
                    and isinstance(getattr(sub, "ctx", None), ast.Load):
                name = dotted_name(sub)
                if name:
                    self.read(name, sub)
        # donations fire after the reads they contain
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                callee = dotted_name(sub.func)
                nums = self.bindings.get(callee or "")
                if not nums:
                    continue
                for pos in nums:
                    if pos < len(sub.args):
                        name = dotted_name(sub.args[pos])
                        if name:
                            self.dead[name] = (callee, sub.lineno)

    def store_target(self, t):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self.store_target(e)
        elif isinstance(t, ast.Starred):
            self.store_target(t.value)
        elif isinstance(t, (ast.Name, ast.Attribute)):
            name = dotted_name(t)
            if name:
                self.store(name)
        elif isinstance(t, ast.Subscript):
            # storing INTO a container reads the container
            name = dotted_name(t.value)
            if name:
                self.read(name, t)
            self.eval_expr(t.slice)

    # --------------------------------------------------------- statements --
    def exec_block(self, stmts):
        for s in stmts:
            self.exec_stmt(s)

    def exec_stmt(self, s):
        if isinstance(s, ast.Assign):
            self.eval_expr(s.value)
            for t in s.targets:
                self.store_target(t)
        elif isinstance(s, ast.AugAssign):
            self.eval_expr(s.value)
            self.eval_expr(s.target)       # aug-assign reads the target
            self.store_target(s.target)
        elif isinstance(s, ast.AnnAssign):
            self.eval_expr(s.value)
            if s.value is not None:
                self.store_target(s.target)
        elif isinstance(s, (ast.Expr, ast.Return)):
            self.eval_expr(s.value)
        elif isinstance(s, ast.Delete):
            for t in s.targets:
                name = dotted_name(t)
                if name:
                    self.store(name)
        elif isinstance(s, ast.If):
            self.eval_expr(s.test)
            before = dict(self.dead)
            self.exec_block(s.body)
            after_body = self.dead
            self.dead = dict(before)
            self.exec_block(s.orelse)
            # dead after the If when dead on ANY path that falls through —
            # a branch ending in return/raise/break/continue never reaches
            # the statements after the If
            body_falls = not _terminates(s.body)
            else_falls = not s.orelse or not _terminates(s.orelse)
            if body_falls and else_falls:
                self.dead = {**self.dead, **after_body}
            elif body_falls:
                self.dead = after_body
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self.eval_expr(s.iter)
            self.store_target(s.target)
            before = dict(self.dead)
            # two passes: catch loop-carried use-after-donate (a donate in
            # iteration N read by iteration N+1 without a rebind)
            self.exec_block(s.body)
            self.exec_block(s.body)
            self.exec_block(s.orelse)
            self.dead = {**before, **self.dead}
        elif isinstance(s, ast.While):
            self.eval_expr(s.test)
            before = dict(self.dead)
            self.exec_block(s.body)
            self.exec_block(s.body)
            self.exec_block(s.orelse)
            self.dead = {**before, **self.dead}
        elif isinstance(s, ast.Try):
            self.exec_block(s.body)
            for h in s.handlers:
                self.exec_block(h.body)
            self.exec_block(s.orelse)
            self.exec_block(s.finalbody)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                self.eval_expr(item.context_expr)
                if item.optional_vars is not None:
                    self.store_target(item.optional_vars)
            self.exec_block(s.body)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            pass   # different frame; handled by its own walk
        else:
            for sub in ast.iter_child_nodes(s):
                if isinstance(sub, ast.expr):
                    self.eval_expr(sub)


def _class_self_bindings(cls: ast.ClassDef, mod: Module) -> dict:
    """``self.X = jax.jit(..., donate_argnums=...)`` across all methods."""
    out = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            call = _as_jit_call(node.value, mod.aliases)
            if call is None:
                continue
            target = dotted_name(node.targets[0])
            nums = _literal_argnums(call)
            if target and target.startswith("self.") and nums:
                out[target] = nums
    return out


@register_rule("use-after-donate")
def check_use_after_donate(mod: Module) -> list:
    findings: list = []
    jb = collect_jit_bindings(mod)
    module_bindings = dict(jb.donated)
    # donated functions imported from sibling modules resolve through the
    # alias map at call sites; the registry here stays module-local, so a
    # `from x import f` of a donated f is covered when x is in this repo
    # and f was collected by ITS module walk — cross-module call sites use
    # the local name, which the import maps to the same donated positions.
    # (In this codebase all donated callables are used module-locally or
    # via self-attributes, so local collection is sufficient.)

    def walk_function(fn, bindings):
        w = _DonateWalker(mod, bindings, findings)
        w.exec_block(fn.body)

    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walk_function(node, module_bindings)
            for sub in ast.walk(node):
                if sub is not node and isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # nested defs see module + their own enclosing bindings
                    walk_function(sub, module_bindings)
        elif isinstance(node, ast.ClassDef):
            self_bindings = _class_self_bindings(node, mod)
            for meth in node.body:
                if isinstance(meth, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    local = dict(module_bindings)
                    local.update(self_bindings)
                    # plus any function-local `f = jax.jit(...)` bindings
                    for sub in ast.walk(meth):
                        if isinstance(sub, ast.Assign) \
                                and len(sub.targets) == 1:
                            call = _as_jit_call(sub.value, mod.aliases)
                            if call is None:
                                continue
                            t = dotted_name(sub.targets[0])
                            nums = _literal_argnums(call)
                            if t and nums and not t.startswith("self."):
                                local[t] = nums
                    walk_function(meth, local)
    # function-local bindings for module-level functions
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local = {}
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                    call = _as_jit_call(sub.value, mod.aliases)
                    if call is not None:
                        t = dotted_name(sub.targets[0])
                        nums = _literal_argnums(call)
                        if t and nums:
                            local[t] = nums
            if local:
                w = _DonateWalker(mod, local, findings)
                w.exec_block(node.body)
    # dedupe (module-level defs are walked with module bindings AND local
    # bindings; identical findings collapse)
    seen, out = set(), []
    for f in findings:
        key = (f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


# =============================================================== host-sync ==
#
# The paper's thesis: unplanned host<->device synchronization points are
# where heterogeneous engines lose. Two checks:
#   1. block_until_ready is only legal at the sanctioned sync sites
#      (core/sync.py — the module whose JOB is synchronization) and in
#      benchmarks (which time against the device by definition).
#   2. Inside traced bodies (functions that end up under jax.jit, and
#      closures handed to lax control flow), pulling a traced value to the
#      host — .item(), np.asarray/np.array, bool()/int()/float(),
#      jax.device_get, or branching on it — either crashes at trace time
#      or silently pins a sync point into the hot loop.

_BLOCK_ALLOWED = ("src/repro/core/sync.py", "benchmarks/")
_NP_SINKS = {"numpy.asarray", "numpy.array", "numpy.asanyarray"}
_LAX_CONTROL = {
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map", "jax.checkpoint",
    "jax.remat", "jax.vmap", "jax.grad", "jax.value_and_grad",
}


def _collect_traced_defs(mod: Module) -> dict:
    """name -> static argnames, for every local def whose body is traced:
    jit-decorated, jit-bound, or passed to lax control flow."""
    traced = dict(collect_jit_bindings(mod).traced_fns)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            name = resolve(dotted_name(node.func), mod.aliases)
            if name in _LAX_CONTROL:
                for arg in node.args:
                    an = dotted_name(arg)
                    if an and "." not in an:
                        traced.setdefault(an, ())
    return traced


class _TaintChecker:
    """Flag host-sync sinks on values tainted by a traced function's
    (non-static) parameters."""

    def __init__(self, mod: Module, findings: list):
        self.mod = mod
        self.findings = findings

    def check(self, fn, static: tuple):
        tainted = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                   + fn.args.kwonlyargs)
                   if a.arg not in static and a.arg != "self"}
        self._walk_body(fn, tainted)

    # trace-time-static attributes: reading x.shape / x.ndim of a traced
    # array yields a python value, not a traced one — no sync involved
    _STATIC_ATTRS = ("shape", "ndim", "dtype", "size", "sharding")

    def _is_tainted(self, expr, tainted) -> bool:
        if isinstance(expr, ast.Attribute) \
                and expr.attr in self._STATIC_ATTRS:
            return False
        if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
                and expr.func.id == "len":
            return False      # len(x) of a traced array is static too
        if isinstance(expr, ast.Name):
            return expr.id in tainted
        return any(self._is_tainted(sub, tainted)
                   for sub in ast.iter_child_nodes(expr))

    def _flag(self, node, what):
        self.findings.append(self.mod.finding(
            "host-sync", node,
            f"{what} on a traced value inside a jitted/scanned body — "
            f"this is a host synchronization point in the hot loop "
            f"(or a trace-time crash)"))

    def _walk_body(self, fn, tainted):
        sinks_builtin = {"bool", "int", "float"}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if self._is_tainted(node.value, tainted):
                    for t in node.targets:
                        for sub in ast.walk(t):
                            if isinstance(sub, ast.Name):
                                tainted.add(sub.id)
            elif isinstance(node, (ast.For,)):
                if self._is_tainted(node.iter, tainted):
                    for sub in ast.walk(node.target):
                        if isinstance(sub, ast.Name):
                            tainted.add(sub.id)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                rname = resolve(dotted_name(node.func), self.mod.aliases)
                if rname in _NP_SINKS or rname == "jax.device_get":
                    if any(self._is_tainted(a, tainted) for a in node.args):
                        self._flag(node, rname)
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    if self._is_tainted(node.func.value, tainted):
                        self._flag(node, ".item()")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in sinks_builtin \
                        and node.func.id not in tainted:
                    if any(self._is_tainted(a, tainted) for a in node.args):
                        self._flag(node, f"{node.func.id}()")
            elif isinstance(node, (ast.If, ast.While)):
                if self._is_tainted(node.test, tainted):
                    self._flag(node, "branching (implicit bool())")
            elif isinstance(node, ast.Assert):
                if self._is_tainted(node.test, tainted):
                    self._flag(node, "assert (implicit bool())")


@register_rule("host-sync")
def check_host_sync(mod: Module) -> list:
    findings: list = []
    # 1. block_until_ready outside the sanctioned sync sites
    if not _allowed(mod.relpath, _BLOCK_ALLOWED):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr == "block_until_ready":
                findings.append(mod.finding(
                    "host-sync", node,
                    "block_until_ready outside core/sync.py and "
                    "benchmarks/ — route the sync through core.sync "
                    "(e.g. fence()) so sync points stay auditable"))
    # 2. host pulls inside traced bodies
    traced = _collect_traced_defs(mod)
    checker = _TaintChecker(mod, findings)
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in traced:
            checker.check(node, tuple(traced[node.name]))
        elif isinstance(node, ast.Lambda):
            pass   # lambda bodies are expressions; sinks there are rare
    # jit-decorated defs not caught by name (decorator form records by name
    # too, so nothing extra to do)
    seen, out = set(), []
    for f in findings:
        key = (f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


# re-export for schema.py / tests
_allowed_paths = _allowed
