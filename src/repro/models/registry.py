"""Uniform model interface over all families.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions:
    init(rng) -> params
    loss(params, inputs, targets) -> (loss, metrics)        [train objective]
    init_cache(batch, max_len, dtype) -> cache
    prefill(params, tokens, cache) -> (last_logits, cache)
    decode_step(params, token, cache) -> (logits, cache)
Encoder-only archs expose ``encode`` instead of prefill/decode.

Attention-family models additionally expose the paged-KV trio used by the
serving scheduler (serving/scheduler.py::PagedBatcher):
    init_paged_cache(num_blocks, block_size, dtype) -> pool
    paged_prefill(params, tokens, pool, block_table, start_index)
        -> (last_logits, pool)
    paged_decode_step(params, token, pool, block_tables, lengths)
        -> (logits, pool)
    paged_verify(params, tokens, pool, block_table, start_index)
        -> (per_position_logits, pool)
``paged_verify`` is the speculative-decoding verification step (one
dispatch scores a lane's pending token plus its K drafted tokens —
serving/spec.py); ``paged_decode_step`` is also the body of the fused-window
decode scan
(core/sync.py::paged_decode_window): it must stay a pure pool -> pool
function of statically-shaped operands so a ``lax.scan`` can carry the pool
across a whole window with zero host round-trips. ``mixed_step`` is the
stage-parallel variant: one dispatch runs every decode lane AND one prefill
chunk of an admitting request against the same pool (the scheduler's
mixed-batch mode), with the same purity/static-shape contract.

All accept ``unroll=`` (roofline cost probes) and ``hetero_ctx=`` (the
HeteroInfer partitioned-matmul context) keyword args where meaningful; the
context covers every partitionable site, including the LM head
(``transformer._head_logits``). Partitioning is an execution schedule, never
a numerics change — any hetero_ctx mode must generate identical tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax.numpy as jnp

from . import mamba2, rwkv6, transformer


@dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    init_cache: Optional[Callable]
    prefill: Optional[Callable]
    decode_step: Optional[Callable]
    encode: Optional[Callable] = None
    # paged KV cache (attention-family models only; None for SSM/RWKV whose
    # recurrent state is O(1) and needs no paging)
    init_paged_cache: Optional[Callable] = None
    paged_prefill: Optional[Callable] = None
    paged_decode_step: Optional[Callable] = None
    # speculative decoding: K+1-position verification in one dispatch
    paged_verify: Optional[Callable] = None
    # stage-parallel mixed batch: one dispatch = batched paged decode step
    # for all lanes + one prefill chunk, sharing a single pool write
    mixed_step: Optional[Callable] = None


def build_model(cfg) -> Model:
    if cfg.rwkv is not None:
        mod = rwkv6
    elif cfg.ssm is not None:
        mod = mamba2
    else:
        mod = transformer

    init = partial(mod.init_params, cfg=cfg)
    loss = partial(mod.loss_fn, cfg=cfg)
    if cfg.encoder_only:
        return Model(cfg=cfg, init=init, loss=loss, init_cache=None,
                     prefill=None, decode_step=None,
                     encode=partial(transformer.forward_hidden, cfg=cfg))
    paged = {}
    if mod is transformer:
        paged = dict(
            init_paged_cache=partial(transformer.init_paged_cache, cfg),
            paged_prefill=partial(transformer.paged_prefill, cfg=cfg),
            paged_decode_step=partial(transformer.paged_decode_step, cfg=cfg),
            paged_verify=partial(transformer.paged_verify, cfg=cfg),
            mixed_step=partial(transformer.mixed_step, cfg=cfg),
        )
    return Model(
        cfg=cfg, init=init, loss=loss,
        init_cache=partial(mod.init_cache, cfg),
        prefill=partial(mod.prefill, cfg=cfg),
        decode_step=partial(mod.decode_step, cfg=cfg),
        **paged,
    )
