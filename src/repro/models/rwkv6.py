"""RWKV-6 ("Finch"): attention-free LM with data-dependent per-channel decay.

Token-mix (WKV6) recurrence per head (state S in R^{hd x hd}):
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(w_base + lora(x_t)))

Runs as a chunked state-passing scan; intra-chunk uses the pairwise log-space
decay tensor (every exponent <= 0 -> no overflow; exact). A per-step scan
(``wkv6_recurrent``) is the oracle; decode uses the exact one-step update.
Simplifications vs the released checkpoints (documented in DESIGN.md):
static token-shift mixing (the data-dependent part retained is the DECAY,
Finch's headline feature), RMSNorm instead of LayerNorm.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import hidden_constraint

from .layers import chunked_ce_loss, rms_norm


def _heads(cfg):
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    return H, hd


def init_layer(key, cfg) -> dict:
    d, r = cfg.d_model, cfg.rwkv.decay_lora
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    n = lambda k, sh, sc=s: (jax.random.normal(k, sh) * sc).astype(dt)
    return {
        "ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt),
        "mix": (0.5 * jnp.ones((5, d))).astype(dt),          # r,k,v,g,w shifts
        "wr": n(ks[0], (d, d)), "wk": n(ks[1], (d, d)),
        "wv": n(ks[2], (d, d)), "wg": n(ks[3], (d, d)),
        "wo": n(ks[4], (d, d)),
        "w_base": (-6.0 * jnp.ones((d,))).astype(jnp.float32),
        "w_lora_a": n(ks[5], (d, r)), "w_lora_b": n(ks[6], (r, d), 0.01),
        "u": (jax.random.normal(ks[7], (d,)) * 0.1).astype(jnp.float32),
        "mix_ffn": (0.5 * jnp.ones((d,))).astype(dt),
        "wk_ffn": n(ks[8], (d, cfg.d_ff)),
        "wv_ffn": (jax.random.normal(ks[9], (cfg.d_ff, d)) / math.sqrt(cfg.d_ff)).astype(dt),
        "wr_ffn": n(jax.random.split(ks[8])[0], (d, d)),
    }


def wkv6_chunked(r, k, v, lw, u, *, chunk: int, state: Optional[jax.Array] = None,
                 unroll: bool = False):
    """r,k,v,lw: [B,S,H,hd]; lw = log decay (<=0). u: [H,hd].
    Returns (y [B,S,H,hd], final state [B,H,hd,hd])."""
    B, S, H, hd = r.shape
    L = min(chunk, S)
    S_orig = S
    if S % L:     # pad with decay=1 (lw=0), k=0 steps: state-neutral
        pad = L - S % L
        pt = lambda a: jnp.pad(a, [(0, 0), (0, pad), (0, 0), (0, 0)])
        r, k, v, lw = pt(r), pt(k), pt(v), pt(lw)
        S += pad
    nc = S // L
    f32 = jnp.float32
    rs = lambda a: a.astype(f32).reshape(B, nc, L, H, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, lwc = rs(r), rs(k), rs(v), rs(lw)
    # exclusive cumsum of log-decay within chunk
    cs = jnp.cumsum(lwc, axis=2) - lwc                     # [nc,B,L,H,hd]
    if state is None:
        state = jnp.zeros((B, H, hd, hd), f32)
    tri_s = jnp.tril(jnp.ones((L, L), bool), -1)           # strict lower

    def step(S_prev, xs):
        r_i, k_i, v_i, lw_i, cs_i = xs                     # [B,L,H,hd]
        # pairwise decay: exp(cs_q - cs_j - lw_j) for j < q  (exponent <= 0
        # on the used strict-lower triangle; clamp the masked rest so the
        # backward pass never sees inf * 0)
        expo = jnp.minimum(
            cs_i[:, :, None] - cs_i[:, None, :] - lw_i[:, None, :], 0.0)
        dec = jnp.where(tri_s[None, :, :, None, None], jnp.exp(expo), 0.0)
        att = jnp.einsum("bqhc,bqjhc,bjhc->bqjh", r_i, dec, k_i)
        y = jnp.einsum("bqjh,bjhd->bqhd", att, v_i)        # intra (strict past)
        y = y + (r_i * u[None, None] * k_i).sum(-1, keepdims=True) * v_i  # u bonus
        y = y + jnp.einsum("bqhc,bhcd->bqhd", r_i * jnp.exp(cs_i), S_prev)
        tot = cs_i[:, -1] + lw_i[:, -1]                    # [B,H,hd] full-chunk sum
        w_k = jnp.exp(tot[:, None] - cs_i - lw_i)          # (<=0 exp)
        S_new = (jnp.exp(tot)[..., None] * S_prev
                 + jnp.einsum("bjhc,bjhd->bhcd", k_i * w_k, v_i))
        return S_new, y

    xs_all = (rc, kc, vc, lwc, cs)
    if unroll:
        ys = []
        for i in range(nc):
            state, y = step(state, jax.tree.map(lambda a: a[i], xs_all))
            ys.append(y)
        y = jnp.stack(ys)
    else:
        state, y = jax.lax.scan(step, state, xs_all)
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y[:, :S_orig], state


def wkv6_recurrent(r, k, v, lw, u, *, state=None):
    """Per-step oracle (exact recurrence)."""
    B, S, H, hd = r.shape
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, hd, hd), f32)

    def step(S_prev, xs):
        r_t, k_t, v_t, lw_t = [a.astype(f32) for a in xs]  # [B,H,hd]
        kv = jnp.einsum("bhc,bhd->bhcd", k_t, v_t)
        y = jnp.einsum("bhc,bhcd->bhd", r_t, S_prev + u[None, ..., None] * kv)
        S_new = jnp.exp(lw_t)[..., None] * S_prev + kv
        return S_new, y

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, lw))
    state, y = jax.lax.scan(step, state, xs)
    return y.transpose(1, 0, 2, 3), state


def _token_mix(p, x, cfg, *, shift_state, wkv_state, unroll, decode=False):
    """x: [B,S,D]. Returns (out, new_shift [B,D], new_wkv [B,H,hd,hd])."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    prev = (jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
            if S > 1 else shift_state[:, None])
    mixed = [x * m + prev * (1 - m) for m in p["mix"]]
    xr, xk, xv, xg, xw = mixed
    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)) \
        @ p["w_lora_b"].astype(jnp.float32)
    lw = -jnp.exp(p["w_base"][None, None] + lora)          # log decay <= 0
    lw = jnp.clip(lw, -40.0, -1e-5).reshape(B, S, H, hd)
    u = p["u"].reshape(H, hd)
    if decode:
        y, new_wkv = wkv6_recurrent(r, k, v, lw, u, state=wkv_state)
    else:
        y, new_wkv = wkv6_chunked(r, k, v, lw, u, chunk=cfg.rwkv.chunk,
                                  state=wkv_state, unroll=unroll)
    y = rms_norm(y.reshape(B * S, H, hd), jnp.ones((hd,), y.dtype),
                 cfg.norm_eps).reshape(B, S, D).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, x[:, -1], new_wkv


def _channel_mix(p, x, *, shift_state):
    B, S, D = x.shape
    prev = (jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
            if S > 1 else shift_state[:, None])
    xk = x * p["mix_ffn"] + prev * (1 - p["mix_ffn"])
    kk = jnp.square(jax.nn.relu(xk @ p["wk_ffn"]))
    rr = jax.nn.sigmoid(x @ p["wr_ffn"])
    return rr * (kk @ p["wv_ffn"]), x[:, -1]


def _layer(lp, x, cfg, st, unroll, decode):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    tm, s1, wkv = _token_mix(lp, h, cfg, shift_state=st["shift1"],
                             wkv_state=st["wkv"], unroll=unroll, decode=decode)
    x = x + tm
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    cm, s2 = _channel_mix(lp, h, shift_state=st["shift2"])
    return hidden_constraint(x + cm), {"shift1": s1, "shift2": s2, "wkv": wkv}


def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, v = cfg.d_model, cfg.vocab_size
    k_emb, k_l, k_head = jax.random.split(key, 3)
    lkeys = jax.random.split(k_l, cfg.n_layers)
    return {
        "embed": (jax.random.normal(k_emb, (v, d)) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), dt),
        "head": (jax.random.normal(k_head, (d, v)) / math.sqrt(d)).astype(dt),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(lkeys),
    }


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    H, hd = _heads(cfg)
    L, d = cfg.n_layers, cfg.d_model
    return {"shift1": jnp.zeros((L, batch, d), dtype),
            "shift2": jnp.zeros((L, batch, d), dtype),
            "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
            "index": jnp.zeros((), jnp.int32)}


def _run(params, x, cfg, *, cache=None, unroll=False, decode=False):
    B = x.shape[0]
    H, hd = _heads(cfg)
    zero_st = lambda: {"shift1": jnp.zeros((B, cfg.d_model), x.dtype),
                       "shift2": jnp.zeros((B, cfg.d_model), x.dtype),
                       "wkv": jnp.zeros((B, H, hd, hd), jnp.float32)}
    if unroll:
        new = {"shift1": [], "shift2": [], "wkv": []}
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            st = (zero_st() if cache is None else
                  {k: cache[k][i] for k in ("shift1", "shift2", "wkv")})
            x, ns = _layer(lp, x, cfg, st, unroll, decode)
            for kk in new:
                new[kk].append(ns[kk])
        nc = {kk: jnp.stack(vv) for kk, vv in new.items()} if cache is not None else None
        return x, nc

    if cache is None:
        def step(x, lp):
            x, _ = _layer(lp, x, cfg, zero_st(), unroll, decode)
            return x, None
        body = step
        if cfg.remat:
            from .layers import remat_policy_of
            body = jax.checkpoint(step, policy=remat_policy_of(cfg))
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, None

    def stepc(x, xs):
        lp, s1, s2, wkv = xs
        x, ns = _layer(lp, x, cfg, {"shift1": s1, "shift2": s2, "wkv": wkv},
                       unroll, decode)
        return x, (ns["shift1"], ns["shift2"], ns["wkv"])

    x, (n1, n2, nw) = jax.lax.scan(
        stepc, x, (params["layers"], cache["shift1"], cache["shift2"],
                   cache["wkv"]))
    return x, {"shift1": n1, "shift2": n2, "wkv": nw}


def loss_fn(params, inputs, targets, cfg, *, unroll=False):
    x = params["embed"][inputs].astype(jnp.dtype(cfg.compute_dtype))
    x, _ = _run(params, x, cfg, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = chunked_ce_loss(params["head"], x, targets, chunk=cfg.loss_chunk,
                         unroll=unroll)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, tokens, cache, cfg, *, start_index=0, unroll=False,
            hetero_ctx=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x, nc = _run(params, x, cfg, cache=cache, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1:, :] @ params["head"]).astype(jnp.float32)
    nc["index"] = jnp.asarray(start_index + tokens.shape[1], jnp.int32)
    return logits, nc


def decode_step(params, token, cache, cfg, *, unroll=False, hetero_ctx=None):
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))
    x, nc = _run(params, x, cfg, cache=cache, decode=True, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    nc["index"] = cache["index"] + 1
    return logits, nc
