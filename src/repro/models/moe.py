"""GShard-style top-k MoE with GROUPED capacity dispatch/combine einsums.

Tokens are split into dispatch groups of ``moe.group_size`` (groups align
with the data-sharded token dim); capacity is per group, so the one-hot
dispatch/combine tensors are [G, Tg, E, Cg] with Tg*E*Cg ~ group^2*k*cf/E —
bounded per device regardless of global batch. Expert tensors reshape to
[E, G*Cg, D] for the expert FFN (MXU-friendly row counts; EP shards E over
the model axis when divisible, else TP on d_ff — see sharding rules).
Differentiable; Switch-style aux load-balance loss returned alongside.

qwen2-moe-style shared experts are a dense SwiGLU (hidden = d_ff_shared)
with a sigmoid shared-expert gate, added to the routed output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import init_swiglu, swiglu


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(keys[0], (d, m.n_experts)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (m.n_experts, d, m.d_ff_expert)) * s).astype(dt),
        "w_up": (jax.random.normal(keys[2], (m.n_experts, d, m.d_ff_expert)) * s).astype(dt),
        "w_down": (jax.random.normal(keys[3], (m.n_experts, m.d_ff_expert, d))
                   / math.sqrt(m.d_ff_expert)).astype(dt),
    }
    if m.d_ff_shared:
        p["shared"] = init_swiglu(keys[4], d, m.d_ff_shared, cfg.param_dtype)
        p["shared_gate"] = jnp.zeros((d, 1), jnp.float32)
    return p


def _group_count(T: int, group_size: int) -> int:
    G = max(1, T // max(group_size, 1))
    while T % G:
        G -= 1
    return G


def moe_ffn(p: dict, x: jax.Array, cfg, hetero_ctx=None):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = _group_count(T, m.group_size)
    Tg = T // G
    xt = x.reshape(G, Tg, D)

    # router product in compute dtype with fp32 accumulation — an fp32 cast
    # of xt would materialize a full fp32 activation copy per layer
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)    # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)        # [G, Tg, k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    E = m.n_experts
    cap = int(max(m.top_k, math.ceil(Tg / E * m.capacity_factor * m.top_k)))
    cap = min(cap, Tg)

    # position of each (token, k) within its expert's per-group queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # [G, Tg, k, E]
    flat = onehot.reshape(G, Tg * m.top_k, E)
    pos = (jnp.cumsum(flat, axis=1) * flat - 1).reshape(
        G, Tg, m.top_k, E).max(-1)                             # [G, Tg, k]
    keep = (pos >= 0) & (pos < cap)
    pos = jnp.where(keep, pos, 0)

    cd = x.dtype
    # build [G, Tg, E, C] dispatch/combine by summing k rank-1 slot products
    # in COMPUTE dtype (no [G,Tg,k,E,C] and no fp32 copies — §Perf moe/i2;
    # gating weights round to bf16, an O(1e-3) relative perturbation)
    disp = jnp.zeros((G, Tg, E, cap), cd)
    combine = jnp.zeros((G, Tg, E, cap), cd)
    for j in range(m.top_k):
        e_oh = (jax.nn.one_hot(gate_idx[..., j], E, dtype=cd)
                * keep[..., j, None].astype(cd))
        c_oh = jax.nn.one_hot(pos[..., j], cap, dtype=cd)
        outer = jnp.einsum("gte,gtc->gtec", e_oh, c_oh)
        disp = disp + outer
        combine = combine + outer * gate_vals[..., j, None, None].astype(cd)

    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xt,
                           preferred_element_type=cd)          # [G, E, C, D]
    ei = expert_in.transpose(1, 0, 2, 3).reshape(E, G * cap, D)
    g = jnp.einsum("ecd,edf->ecf", ei, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", ei, p["w_up"])
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])
    eo = eo.reshape(E, G, cap, D).transpose(1, 0, 2, 3)        # [G, E, C, D]
    out = jnp.einsum("gtec,gecd->gtd", combine, eo,
                     preferred_element_type=cd)

    # Switch aux loss: E * mean_g sum_e f_e * P_e
    f = (disp.sum(-1) > 0).astype(jnp.float32).mean(1)         # [G, E]
    pm = probs.mean(1)
    aux = E * jnp.mean(jnp.sum(f * pm, axis=-1))

    out = out.reshape(T, D)
    if m.d_ff_shared:
        xt2 = x.reshape(T, D)
        sg = jax.nn.sigmoid(xt2.astype(jnp.float32) @ p["shared_gate"]).astype(cd)
        out = out + sg * swiglu(p["shared"], xt2, hetero_ctx=hetero_ctx)
    return out.reshape(B, S, D), aux
