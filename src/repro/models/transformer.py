"""Decoder-only (and encoder-only) transformer LM: dense / MoE / VLM / audio.

Layer stack runs under ``lax.scan`` over stacked per-layer params (small HLO;
the production posture for 1000+-node compile times). ``unroll=True`` switches
every loop to Python for the roofline cost probes.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.partition import matmul_any
from repro.distributed.sharding import hidden_constraint

from .layers import (attention, chunked_ce_loss, init_attention, init_swiglu,
                     paged_attention, rms_norm, swiglu)
from .moe import init_moe, moe_ffn


def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    d, v = cfg.d_model, cfg.vocab_size
    params = {
        "embed": (jax.random.normal(k_emb, (v, d)) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(k_head, (d, v)) / math.sqrt(d)).astype(dt)

    def init_layer(k):
        ka, kf = jax.random.split(k)
        lp = {
            "attn_norm": jnp.ones((d,), dt),
            "attn": init_attention(ka, cfg),
            "ffn_norm": jnp.ones((d,), dt),
        }
        if cfg.moe:
            lp["moe"] = init_moe(kf, cfg)
        else:
            lp["ffn"] = init_swiglu(kf, d, cfg.d_ff, cfg.param_dtype)
        return lp

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(init_layer)(layer_keys)
    return params


def _layer(lp, x, cfg, *, positions, kv=None, cache_index=None, unroll=False,
           hetero_ctx=None, paged=None, tp_axis=None):
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    if paged is not None:
        attn_out, new_kv = paged_attention(
            lp["attn"], h, cfg, positions=positions,
            pool=paged["pool"], block_table=paged["block_table"],
            unroll=unroll, hetero_ctx=hetero_ctx, tp_axis=tp_axis)
    else:
        attn_out, new_kv = attention(lp["attn"], h, cfg, positions=positions,
                                     cache=kv, cache_index=cache_index,
                                     unroll=unroll, hetero_ctx=hetero_ctx)
    x = x + attn_out
    h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.moe:
        ffn_out, aux = moe_ffn(lp["moe"], h, cfg, hetero_ctx=hetero_ctx)
    else:
        ffn_out, aux = swiglu(lp["ffn"], h, hetero_ctx=hetero_ctx,
                              tp_axis=tp_axis), jnp.zeros((), jnp.float32)
    return hidden_constraint(x + ffn_out), new_kv, aux


def _embed(params, inputs, cfg):
    if inputs.dtype in (jnp.int32, jnp.int64):
        return params["embed"][inputs].astype(jnp.dtype(cfg.compute_dtype))
    return inputs.astype(jnp.dtype(cfg.compute_dtype))   # modality-stub embeddings


def _run_layers(params, x, cfg, *, positions, cache=None, cache_index=None,
                unroll=False, hetero_ctx=None):
    """Apply all layers; returns (x, new_cache_kv_stacked, aux_sum)."""
    L = cfg.n_layers

    def body(x, lp, kv):
        return _layer(lp, x, cfg, positions=positions, kv=kv,
                      cache_index=cache_index, unroll=unroll,
                      hetero_ctx=hetero_ctx)

    if unroll:
        new_ks, new_vs, aux = [], [], jnp.zeros((), jnp.float32)
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            kv = (None if cache is None else
                  {"k": cache["k"][i], "v": cache["v"][i]})
            x, nkv, a = body(x, lp, kv)
            aux = aux + a
            if nkv is not None:
                new_ks.append(nkv["k"]); new_vs.append(nkv["v"])
        nc = ({"k": jnp.stack(new_ks), "v": jnp.stack(new_vs)}
              if new_ks else None)
        return x, nc, aux

    if cache is None:
        def step(carry, lp):
            x, aux = carry
            fn = body
            if cfg.remat:
                from .layers import remat_policy_of
                fn = jax.checkpoint(lambda x, lp: body(x, lp, None)[::2],
                                    policy=remat_policy_of(cfg))
                x2, a = fn(x, lp)
            else:
                x2, _, a = body(x, lp, None)
            return (x2, aux + a), None
        (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return x, None, aux

    def step(carry, xs):
        x, aux = carry
        lp, k_l, v_l = xs
        x2, nkv, a = body(x, lp, {"k": k_l, "v": v_l})
        return (x2, aux + a), (nkv["k"], nkv["v"])

    (x, aux), (nk, nv) = jax.lax.scan(
        step, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], cache["k"], cache["v"]))
    return x, {"k": nk, "v": nv}, aux


def _head_matrix(params, cfg):
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


def _head_logits(params, x, cfg, hetero_ctx=None, tp_axis=None):
    """LM-head matmul — a partitionable site like any other (the latency
    table profiles it as "head"), so inference paths route it through the
    HeteroCtx when one is given. Under tensor parallelism an untied head is
    vocab-column sharded: local logits are gathered along V (bit-exact
    column concatenation); a tied head reads the replicated embedding and
    needs no collective."""
    if hetero_ctx is not None:
        y = hetero_ctx.matmul(x, _head_matrix(params, cfg), name="head")
    else:
        y = matmul_any(x, _head_matrix(params, cfg))
    y = y.astype(jnp.float32)
    if tp_axis is not None and not cfg.tie_embeddings:
        from .layers import tp_all_gather
        y = tp_all_gather(y, tp_axis)
    return y


def loss_fn(params, inputs, targets, cfg, *, unroll=False):
    """Training objective: next-token CE (+ MoE aux). inputs [B,S] or [B,S,D]."""
    S = inputs.shape[1]
    x = _embed(params, inputs, cfg)
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, aux = _run_layers(params, x, cfg, positions=positions, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = chunked_ce_loss(_head_matrix(params, cfg), x, targets,
                         chunk=cfg.loss_chunk, unroll=unroll)
    return ce + 0.01 * aux / max(cfg.n_layers, 1), {"ce": ce, "aux": aux}


def forward_hidden(params, inputs, cfg, *, unroll=False):
    """Full-sequence hidden states (no cache) — used by encoder eval."""
    S = inputs.shape[1]
    x = _embed(params, inputs, cfg)
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, _ = _run_layers(params, x, cfg, positions=positions, unroll=unroll)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.zeros((), jnp.int32)}


def prefill(params, tokens, cache, cfg, *, start_index=0, unroll=False,
            hetero_ctx=None):
    """Process a prompt (or prompt chunk, for chunked prefill), write the
    cache at [start_index, start_index+S), return last-token logits."""
    B, S = tokens.shape[0], tokens.shape[1]
    x = _embed(params, tokens, cfg)
    positions = start_index + jnp.arange(S, dtype=jnp.int32)
    x, nkv, _ = _run_layers(params, x, cfg, positions=positions,
                            cache=cache, cache_index=start_index,
                            unroll=unroll, hetero_ctx=hetero_ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x[:, -1:, :], cfg, hetero_ctx)
    return logits, {"k": nkv["k"], "v": nkv["v"],
                    "index": jnp.asarray(start_index + S, jnp.int32)}


def prefill_slot(params, cache, tokens, slot, start, cfg, *, chunk: int):
    """Prefill one prompt chunk of one request into ``slot`` of a batched
    dense cache (``[L, B, S, Hkv, D]``): slice the slot out, run
    :func:`prefill` at ``start``, write the updated KV back. Shared by the
    dense continuous batcher's admission path and the speculative
    decoder's draft-lane prefill (serving/spec.py). ``chunk`` is unused in
    the body — callers jit with ``static_argnames=('chunk',)`` so each
    bucket length keys its own compiled graph."""
    sub = {"k": jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1),
           "v": jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1),
           "index": start}
    logits, new = prefill(params, tokens[None, :], sub, cfg,
                          start_index=start)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], new["k"], slot, axis=1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], new["v"], slot, axis=1)
    return logits, cache


# ------------------------------------------------------------ paged cache --

def init_paged_cache(cfg, *, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, kv_quant: Optional[str] = None
                     ) -> dict:
    """Shared KV page pool: ``[L, num_blocks, block_size, Hkv, D]`` per
    tensor. Block 0 is the null block (see serving/paged_cache.py).

    ``kv_quant='int8'`` stores int8 codes plus one scale scalar per
    (layer, slot, tensor) — ``k_scale``/``v_scale`` ``[L, NB, BS]`` in
    bfloat16, quantized-on-scatter and dequantized in the attention gather
    (models/layers.py::paged_attention). Zero scales mark unwritten slots.
    """
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    if kv_quant is None:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kv_quant != "int8":
        raise ValueError(f"unsupported kv_quant {kv_quant!r}")
    sshape = shape[:3]
    return {"k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.bfloat16),
            "v_scale": jnp.zeros(sshape, jnp.bfloat16)}


def _run_layers_paged(params, x, cfg, *, positions, pool, block_table,
                      unroll=False, hetero_ctx=None, tp_axis=None):
    """Like ``_run_layers`` but attention reads/writes the paged pool;
    scans over (layer params, per-layer pages) — the pool is a pytree of
    ``[L, ...]`` leaves (K/V tensors plus the int8 pool's scale planes), so
    the scan slices every leaf per layer. Returns the updated pool."""
    if unroll:
        new_pools = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            pl = jax.tree.map(lambda a: a[i], pool)
            x, npl, _ = _layer(lp, x, cfg, positions=positions, unroll=True,
                               hetero_ctx=hetero_ctx, tp_axis=tp_axis,
                               paged={"pool": pl,
                                      "block_table": block_table})
            new_pools.append(npl)
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *new_pools)

    def step(carry, xs):
        lp, pl = xs
        x2, npl, _ = _layer(lp, carry, cfg, positions=positions,
                            hetero_ctx=hetero_ctx, tp_axis=tp_axis,
                            paged={"pool": pl,
                                   "block_table": block_table})
        return x2, npl

    x, new_pool = jax.lax.scan(step, x, (params["layers"], pool))
    return x, new_pool


def paged_prefill(params, tokens, pool, cfg, *, block_table, start_index=0,
                  unroll=False, hetero_ctx=None, tp_axis=None):
    """Prefill a prompt chunk into the request's pages. tokens: [B, S];
    block_table: [B, NBmax]. ``start_index`` is a scalar (uniform batches —
    chunked prefill resuming at the chunk offset, or a cached-prefix suffix
    resuming after the resident prefix) or [B] per-lane starts (the
    ``paged_verify`` nonzero-start machinery, generalized here so batched
    suffix prefill can resume each lane at its own cached-prefix length).
    Returns (last-token logits, updated pool)."""
    S = tokens.shape[1]
    x = _embed(params, tokens, cfg)
    start_index = jnp.asarray(start_index, jnp.int32)
    steps = jnp.arange(S, dtype=jnp.int32)
    positions = (start_index[:, None] + steps[None, :]
                 if start_index.ndim == 1 else start_index + steps)
    x, pool = _run_layers_paged(params, x, cfg, positions=positions,
                                pool=pool, block_table=block_table,
                                unroll=unroll, hetero_ctx=hetero_ctx,
                                tp_axis=tp_axis)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x[:, -1:, :], cfg, hetero_ctx, tp_axis)
    return logits, pool


def paged_verify(params, tokens, pool, cfg, *, block_table, start_index,
                 unroll=False, hetero_ctx=None, tp_axis=None):
    """Speculative-decoding verification step: append ``tokens`` ([B, K+1] —
    each lane's pending token plus its K drafted tokens) after each lane's
    cached prefix and return PER-POSITION logits over all K+1 positions.

    Generalizes the two existing paged inference steps: ``paged_prefill``
    runs many tokens but emits only last-token logits; ``paged_decode_step``
    emits per-position logits but for one token (this is the K=0 case).
    Verification needs both: every position's logits feed the greedy
    accept/reject rule (serving/sampler.py::greedy_verify), and rejected
    positions are reclaimed afterwards by ``PagedKVCache.truncate_to``
    (stale pool slots past the accepted prefix are masked positionally and
    rewritten before any later query attends them, so rollback is free on
    the device side).

    ``start_index``: [B] per-lane write positions (like ``paged_decode_step``
    lengths), or a scalar for uniform batches. The K-token matmuls are an
    M=K+1-shaped site class of their own — a ``hetero_ctx`` built with
    ``verify_ks`` routes them through the solver's VERIFY decisions.
    Returns (logits [B, K+1, V], updated pool).
    """
    S = tokens.shape[1]
    start_index = jnp.asarray(start_index, jnp.int32)
    steps = jnp.arange(S, dtype=jnp.int32)
    positions = (start_index[:, None] + steps[None, :]
                 if start_index.ndim == 1 else start_index + steps)
    x = _embed(params, tokens, cfg)
    x, pool = _run_layers_paged(params, x, cfg, positions=positions,
                                pool=pool, block_table=block_table,
                                unroll=unroll, hetero_ctx=hetero_ctx,
                                tp_axis=tp_axis)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x, cfg, hetero_ctx, tp_axis)
    return logits, pool


def mixed_step(params, decode_tokens, prefill_tokens, pool, cfg, *,
               decode_tables, decode_lengths, prefill_table, prefill_start=0,
               unroll=False, hetero_ctx=None, tp_axis=None):
    """Stage-parallel mixed batch: ONE dispatch runs a batched paged decode
    step for every lane AND one prefill chunk of an admitting request,
    sharing a single paged-pool write (paper §4.1-§4.3 applied at stage
    level: decode is the memory-bound flexible-path stream, the aligned
    prefill chunk is the compute-bound MXU-path stream, and running them
    concurrently is what fills both the compute and bandwidth envelopes).

    decode_tokens: [W, 1]; prefill_tokens: [1, C]; decode_tables: [W, NBmax];
    decode_lengths: [W]; prefill_table: [1, NBmax]. The two streams touch
    disjoint pool blocks (the allocator never shares a block), so fusion is
    an execution-schedule change, never a numerics change. Decode lanes stay
    on the flexible path (no hetero_ctx — they are Memory-1 bound); the
    prefill chunk routes its matmuls through ``hetero_ctx`` when given.

    Returns (decode_logits [W, 1, V], prefill_logits [1, 1, V], pool).
    """
    xd = _embed(params, decode_tokens, cfg)
    xp = _embed(params, prefill_tokens, cfg)
    C = prefill_tokens.shape[1]
    dec_pos = decode_lengths[:, None].astype(jnp.int32)
    pre_pos = prefill_start + jnp.arange(C, dtype=jnp.int32)

    def body(lp, xd, xp, pl):
        # decode lanes first (flexible path), prefill chunk second
        # (solver-planned path); order is arbitrary — disjoint block tables
        xd2, npd, _ = _layer(lp, xd, cfg, positions=dec_pos, unroll=unroll,
                             tp_axis=tp_axis,
                             paged={"pool": pl,
                                    "block_table": decode_tables})
        xp2, npp, _ = _layer(lp, xp, cfg, positions=pre_pos, unroll=unroll,
                             hetero_ctx=hetero_ctx, tp_axis=tp_axis,
                             paged={"pool": npd,
                                    "block_table": prefill_table})
        return xd2, xp2, npp

    if unroll:
        new_pools = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            pl = jax.tree.map(lambda a: a[i], pool)
            xd, xp, npl = body(lp, xd, xp, pl)
            new_pools.append(npl)
        pool = jax.tree.map(lambda *ls: jnp.stack(ls), *new_pools)
    else:
        def step(carry, xs):
            xd, xp = carry
            lp, pl = xs
            xd2, xp2, npl = body(lp, xd, xp, pl)
            return (xd2, xp2), npl

        (xd, xp), pool = jax.lax.scan(
            step, (xd, xp), (params["layers"], pool))

    xd = rms_norm(xd, params["final_norm"], cfg.norm_eps)
    dec_logits = _head_logits(params, xd, cfg, None, tp_axis)  # flexible path
    xp = rms_norm(xp, params["final_norm"], cfg.norm_eps)
    pre_logits = _head_logits(params, xp[:, -1:, :], cfg, hetero_ctx, tp_axis)
    return dec_logits, pre_logits, pool


def paged_decode_step(params, token, pool, cfg, *, block_tables, lengths,
                      unroll=False, hetero_ctx=None, tp_axis=None):
    """One batched decode step over the page pool. token: [B, 1];
    block_tables: [B, NBmax]; lengths: [B] per-request write positions.
    Inactive lanes (length 0, null table) sink writes into the null block.
    Returns (logits [B, 1, V], updated pool)."""
    x = _embed(params, token, cfg)
    positions = lengths[:, None].astype(jnp.int32)
    x, pool = _run_layers_paged(params, x, cfg, positions=positions,
                                pool=pool, block_table=block_tables,
                                unroll=unroll, hetero_ctx=hetero_ctx,
                                tp_axis=tp_axis)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x, cfg, hetero_ctx, tp_axis)
    return logits, pool


def decode_step(params, token, cache, cfg, *, unroll=False, hetero_ctx=None):
    """One autoregressive step. token: [B, 1] int32. Returns (logits, cache).
    ``cache['index']`` may be a scalar (uniform batch) or [B] per-slot
    lengths (continuous batching)."""
    idx = cache["index"]
    x = _embed(params, token, cfg)
    positions = (idx[:, None].astype(jnp.int32) if jnp.ndim(idx) == 1
                 else jnp.full((1,), idx, jnp.int32))
    x, nkv, _ = _run_layers(params, x, cfg, positions=positions,
                            cache=cache, cache_index=idx, unroll=unroll,
                            hetero_ctx=hetero_ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x, cfg, hetero_ctx)
    return logits, {"k": nkv["k"], "v": nkv["v"], "index": idx + 1}
