"""Shared model layers (pure functions over param pytrees).

Attention is blockwise with an online softmax (O(S * block) memory) so 32k
prefill and 4k training never materialize S^2 score tensors in the pure-JAX
path. Every internal loop honors ``unroll``: ``lax.scan`` normally (small HLO,
fast compiles), Python loop in cost-probe mode (so ``cost_analysis`` sees the
full FLOP count; see roofline methodology in DESIGN.md §6).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import matmul_any

NEG_INF = -1e30


def tp_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Reassemble a column-sharded tensor along its LAST axis, concatenating
    the per-shard blocks in shard order. Every output column is produced by
    exactly one shard with the same reduction order as the unsharded matmul,
    so tensor-parallel execution under this gather is bit-exact with the
    single-device path (no psum-of-partials reassociation)."""
    return jax.lax.all_gather(x, axis_name, axis=x.ndim - 1, tiled=True)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """fp32 statistics WITHOUT materializing an fp32 copy of x: the square/
    convert fuse into the reduction; the big tensors stay in compute dtype
    (§Perf train/i2 — fp32 norm copies dominated HBM traffic)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


def rope_freqs(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))          # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def remat_policy_of(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _scan_or_unroll(step, carry, xs_leaves, n: int, unroll: bool):
    """scan over leading axis of each leaf in xs_leaves, or a Python loop."""
    if not unroll:
        carry, _ = jax.lax.scan(lambda c, xs: (step(c, xs), None), carry, xs_leaves)
        return carry
    for i in range(n):
        carry = step(carry, jax.tree.map(lambda a: a[i], xs_leaves))
    return carry


def blockwise_attention(
    q: jax.Array,                 # [B, Sq, Hq, D]
    k: jax.Array,                 # [B, Sk, Hkv, D]
    v: jax.Array,                 # [B, Sk, Hkv, D]
    *,
    q_pos: jax.Array,             # [Sq] or [B, Sq] int32 absolute positions
    kv_pos: jax.Array,            # [Sk] int32
    causal: bool = True,
    block_k: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax attention over KV blocks (GQA-aware). fp32 accumulation.

    Causal masking is positional (kv_pos <= q_pos), which also masks unwritten
    KV-cache slots during decode (their kv_pos exceeds the query position).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, Sq))

    block_k = min(block_k, Sk)
    padded = bool(Sk % block_k)
    if padded:                            # pad KV to a block multiple; padded
        pad = block_k - Sk % block_k      # slots get kv_pos = INT_MAX -> masked
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
        Sk += pad
    max_kv_pos = None if causal else kv_pos[-1 - (pad if padded else 0)]
    nb = Sk // block_k
    kb = k.reshape(B, nb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(nb, block_k)

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    masked = causal or padded

    def step(carry, blk):
        m, l, acc = carry
        k_b, v_b, p_b = blk
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_b,
                       preferred_element_type=jnp.float32) * scale
        if masked:
            if causal:
                mask = p_b[None, None, :] <= q_pos[:, :, None]      # [B,Sq,bk]
            else:  # bidirectional but padded: validity only
                mask = jnp.broadcast_to((p_b <= max_kv_pos)[None, None, :],
                                        (B, Sq, block_k))
            mask = mask[:, :, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if masked:
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v_b.dtype), v_b,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    m, l, acc = _scan_or_unroll(step, (m0, l0, a0), (kb, vb, pb), nb, unroll)
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def dense_attention(q, k, v, *, q_pos, kv_pos, causal=True) -> jax.Array:
    """Reference O(S^2)-memory attention (oracle for tests)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if causal:
        if q_pos.ndim == 1:
            q_pos = jnp.broadcast_to(q_pos[None, :], (B, Sq))
        mask = kv_pos[None, None, :] <= q_pos[:, :, None]
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------- attention --

def init_attention(key, cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": (jax.random.normal(k1, (d, cfg.n_heads * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, cfg.n_kv_heads * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, cfg.n_kv_heads * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (cfg.n_heads * hd, d)) * s).astype(dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv_rope(p: dict, x: jax.Array, cfg, positions: jax.Array, hetero_ctx):
    """Shared projection front-end: q/k/v matmuls, qk-norm, RoPE at the
    tokens' absolute positions. Used by both the dense-cache and paged
    attention paths so their numerics are identical by construction."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    mm = hetero_ctx.matmul if hetero_ctx is not None else matmul_any
    q = mm(x, p["wq"], name="wq").reshape(B, S, cfg.n_heads, hd)
    k = mm(x, p["wk"], name="wk").reshape(B, S, cfg.n_kv_heads, hd)
    v = mm(x, p["wv"], name="wv").reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v, mm


def attention(
    p: dict, x: jax.Array, cfg, *,
    positions: jax.Array,
    cache: Optional[dict] = None,        # {"k","v": [B,Smax,Hkv,D], "pos": [Smax]}
    cache_index: Optional[jax.Array] = None,
    unroll: bool = False,
    hetero_ctx=None,
):
    """GQA attention. If ``cache`` is given, new K/V are written at
    ``cache_index`` and attention runs over the whole (masked) cache.
    Returns (out, new_cache_kv or None)."""
    B, S, d = x.shape
    hd = cfg.head_dim
    q, k, v, mm = _qkv_rope(p, x, cfg, positions, hetero_ctx)

    causal = not cfg.encoder_only
    if cache is not None and S == 1:
        from repro.distributed.sharding import split_kv_active
        idx0 = jnp.asarray(cache_index)
        if split_kv_active() and idx0.ndim == 0:
            from repro.distributed.split_kv import split_kv_decode_update_attend
            o, ck, cv = split_kv_decode_update_attend(
                q, k, v, cache["k"], cache["v"], idx0.astype(jnp.int32))
            out = mm(o.reshape(B, S, cfg.n_heads * hd), p["wo"], name="wo")
            return out, {"k": ck, "v": cv}
    if cache is not None:
        idx = jnp.asarray(cache_index)
        if idx.ndim == 1:        # per-slot indices (continuous batching)
            upd = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (i, 0, 0)))
            ck = upd(cache["k"], k.astype(cache["k"].dtype), idx)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), idx)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        Smax = ck.shape[1]
        kv_pos = jnp.arange(Smax, dtype=jnp.int32)
        o = blockwise_attention(q, ck, cv, q_pos=positions, kv_pos=kv_pos,
                                causal=True, block_k=cfg.attn_block_k,
                                unroll=unroll)
        new_kv = {"k": ck, "v": cv}
    else:
        kv_pos = jnp.arange(S, dtype=jnp.int32)
        o = blockwise_attention(q, k, v, q_pos=positions, kv_pos=kv_pos,
                                causal=causal, block_k=cfg.attn_block_k,
                                unroll=unroll)
        new_kv = None
    out = mm(o.reshape(B, S, cfg.n_heads * hd), p["wo"], name="wo")
    return out, new_kv


def quantize_kv_slot(x: jax.Array, scale_dtype=jnp.bfloat16,
                     tp_axis: Optional[str] = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Per-token-slot symmetric int8 KV quantization. x: [T, Hkv, D] ->
    (codes int8 [T, Hkv, D], scale [T]).

    One scalar scale per (token, tensor) slot, ROUNDED TO THE STORAGE DTYPE
    BEFORE the codes are computed: codes quantize against the value the
    gather will actually multiply by, so quantize-on-scatter composes
    bit-exactly across any chunking of the same token stream (prefill vs
    decode vs mixed vs verify writes). An all-zero slot stores scale 0 —
    it dequantizes to exactly 0, like an unwritten fp pool slot.

    Under head-sharded tensor parallelism (``tp_axis`` set inside a
    shard_map) each shard sees only its local KV heads, but the slot scale
    is defined over ALL heads — a pmax over the tp axis recovers the exact
    global amax (max-of-maxes is exact), so codes and scales stay
    bit-identical to the single-device pool.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1))   # [T]
    if tp_axis is not None:
        amax = jax.lax.pmax(amax, tp_axis)
    s_stored = jnp.where(amax > 0, amax / 127.0, 0.0).astype(scale_dtype)
    denom = jnp.where(s_stored == 0, 1.0, s_stored.astype(jnp.float32))
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / denom[..., None, None]),
                     -127, 127).astype(jnp.int8)
    return codes, s_stored


def dequant_kv_ref(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Reference expansion of an int8 KV pool tensor (codes [..., T, Hkv, D]
    x scale [..., T]) — the conformance oracle and the gather-side math."""
    return (codes.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None, None]).astype(dtype)


def paged_attention(
    p: dict, x: jax.Array, cfg, *,
    positions: jax.Array,           # [S] or [B, S] absolute token positions
    pool: dict,                     # per-layer pool: {"k","v": [NB,BS,Hkv,D]}
    #                                 (+ "k_scale","v_scale": [NB,BS] if int8)
    block_table: jax.Array,         # [B, NBmax] int32 pool block ids (0=null)
    unroll: bool = False,
    hetero_ctx=None,
    tp_axis: Optional[str] = None,
):
    """GQA attention over a paged KV pool (serving/paged_cache.py).

    Logical position ``t`` of request ``b`` lives at physical slot
    ``block_table[b, t // BS] * BS + t % BS`` of the flat pool. New K/V are
    scattered there (``.at[idx].set`` — jittable); reads gather the
    request's pages with ``jnp.take`` into a ``[B, NBmax*BS]`` view whose
    slot index IS the logical position, so the standard positional causal
    mask handles stale pool contents and null-block padding exactly like
    the dense path masks unwritten cache slots.

    An int8 pool (``k_scale``/``v_scale`` present) quantizes on scatter —
    per-slot codes + one scale scalar per (slot, tensor) — and dequantizes
    inside the gather, so equal pool memory holds ~2x the blocks while the
    attention math itself stays in compute precision.

    With ``tp_axis`` set (inside a shard_map whose mesh axis carries the KV
    heads), ``cfg`` holds the LOCAL head counts, the pool leaves are local
    head slices, and the whole scatter/gather/softmax runs shard-local; the
    only collectives are the head gather before ``wo`` and the output-column
    gather after it (both [B, S, d]-sized, bit-exact concatenations).

    Returns (out, updated per-layer pool dict with the same keys).
    """
    B, S, _ = x.shape
    hd = cfg.head_dim
    NB, BS, Hkv, D = pool["k"].shape
    quant = "k_scale" in pool
    q, k, v, mm = _qkv_rope(p, x, cfg, positions, hetero_ctx)

    pos = (positions if positions.ndim == 2
           else jnp.broadcast_to(positions[None, :], (B, S))).astype(jnp.int32)
    blk = jnp.take_along_axis(block_table, pos // BS, axis=1)     # [B, S]
    flat_idx = (blk * BS + pos % BS).reshape(-1)                  # [B*S]
    fk = pool["k"].reshape(NB * BS, Hkv, D)
    fv = pool["v"].reshape(NB * BS, Hkv, D)
    new_pool = {}
    if quant:
        k_codes, k_sc = quantize_kv_slot(k.reshape(B * S, Hkv, D),
                                         pool["k_scale"].dtype, tp_axis)
        v_codes, v_sc = quantize_kv_slot(v.reshape(B * S, Hkv, D),
                                         pool["v_scale"].dtype, tp_axis)
        fk = fk.at[flat_idx].set(k_codes)
        fv = fv.at[flat_idx].set(v_codes)
        new_pool["k_scale"] = pool["k_scale"].reshape(
            NB * BS).at[flat_idx].set(k_sc).reshape(NB, BS)
        new_pool["v_scale"] = pool["v_scale"].reshape(
            NB * BS).at[flat_idx].set(v_sc).reshape(NB, BS)
    else:
        fk = fk.at[flat_idx].set(k.reshape(B * S, Hkv, D).astype(fk.dtype))
        fv = fv.at[flat_idx].set(v.reshape(B * S, Hkv, D).astype(fv.dtype))
    new_pool["k"] = fk.reshape(NB, BS, Hkv, D)
    new_pool["v"] = fv.reshape(NB, BS, Hkv, D)

    NBmax = block_table.shape[1]
    ck = jnp.take(new_pool["k"], block_table, axis=0).reshape(
        B, NBmax * BS, Hkv, D)
    cv = jnp.take(new_pool["v"], block_table, axis=0).reshape(
        B, NBmax * BS, Hkv, D)
    if quant:
        ck_s = jnp.take(new_pool["k_scale"], block_table, axis=0).reshape(
            B, NBmax * BS)
        cv_s = jnp.take(new_pool["v_scale"], block_table, axis=0).reshape(
            B, NBmax * BS)
        ck = dequant_kv_ref(ck, ck_s, q.dtype)
        cv = dequant_kv_ref(cv, cv_s, q.dtype)
    kv_pos = jnp.arange(NBmax * BS, dtype=jnp.int32)
    o = blockwise_attention(q, ck, cv, q_pos=pos, kv_pos=kv_pos,
                            causal=True, block_k=cfg.attn_block_k,
                            unroll=unroll)
    o = o.reshape(B, S, cfg.n_heads * hd)
    if tp_axis is not None:
        o = tp_all_gather(o, tp_axis)       # local heads -> full head dim
    out = mm(o, p["wo"], name="wo")
    if tp_axis is not None:
        out = tp_all_gather(out, tp_axis)   # wo is output-column sharded
    return out, new_pool


# ---------------------------------------------------------------------- ffn --

def init_swiglu(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    dt = jnp.dtype(dtype)
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * s).astype(dt),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s).astype(dt),
        "w_down": (jax.random.normal(k3, (d_ff, d)) / math.sqrt(d_ff)).astype(dt),
    }


def swiglu(p: dict, x: jax.Array, hetero_ctx=None,
           tp_axis: Optional[str] = None) -> jax.Array:
    """With ``tp_axis`` set, w_gate/w_up are column-sharded (local d_ff
    slice) and w_down is output-column sharded: the hidden activation and
    the output are reassembled with bit-exact tiled all-gathers instead of
    a psum of row-parallel partials (which would reassociate the d_ff
    reduction and drift from the single-device numerics)."""
    mm = hetero_ctx.matmul if hetero_ctx is not None else matmul_any
    g = mm(x, p["w_gate"], name="w_gate")
    u = mm(x, p["w_up"], name="w_up")
    h = jax.nn.silu(g) * u
    if tp_axis is not None:
        h = tp_all_gather(h, tp_axis)       # local d_ff columns -> full d_ff
    out = mm(h, p["w_down"], name="w_down")
    if tp_axis is not None:
        out = tp_all_gather(out, tp_axis)   # w_down output-column sharded
    return out


# ----------------------------------------------------------------- lm head --

def chunked_ce_loss(emb_out: jax.Array, h: jax.Array, targets: jax.Array,
                    *, chunk: int, unroll: bool = False) -> jax.Array:
    """Cross-entropy without materializing [B, S, V]: scan over seq chunks.

    emb_out: [D, V] (output head, possibly tied transpose); h: [B, S, D];
    targets: [B, S] int32. Returns mean loss (fp32).

    The per-chunk logits are constrained to shard over the model axis on V
    (§Perf train/i1): an unsharded [B, c, V] fp32 logits buffer dominates
    HBM traffic at 100k-class vocabs; V-sharding divides it by the TP width
    (logsumexp then reduces over the sharded axis -> one tiny all-reduce).
    """
    from repro.distributed.sharding import logits_constraint

    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk:            # largest divisor of S at most `chunk`
        chunk -= 1
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    # checkpointed: WITHOUT this, autodiff-of-scan stacks every chunk's fp32
    # logits as residuals — ~12 GB/device at dbrx scale (§Perf train/i2);
    # recomputing the chunk logits in backward costs one extra head matmul.
    @jax.checkpoint
    def step(carry, xs):
        hi, ti = xs
        logits = logits_constraint(matmul_any(hi, emb_out).astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold)

    total = _scan_or_unroll(step, jnp.zeros((), jnp.float32), (hc, tc), nc,
                            unroll)
    return total / (B * S)
