"""Mamba2 (SSD) blocks + the zamba2-style hybrid assembly.

SSD runs as a chunked scan: intra-chunk pairwise decay (all exponents <= 0,
numerically safe), inter-chunk state passing. Decode is the exact one-step
recurrence. The hybrid model interleaves a SHARED attention+FFN block (single
param set, zamba2-style) every ``ssm.attn_every`` mamba layers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import hidden_constraint

from .layers import (attention, chunked_ce_loss, init_attention, init_swiglu,
                     rms_norm, swiglu, _scan_or_unroll)


# ------------------------------------------------------------- mamba2 block --

def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return d_in, nh, conv_dim


def init_mamba_block(key, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.d_state + nh     # z, x, B, C, dt
    return {
        "norm": jnp.ones((d,), dt),
        "in_proj": (jax.random.normal(k1, (d, proj_out)) / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_dim)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), dt),
        "out_proj": (jax.random.normal(k3, (d_in, d)) / math.sqrt(d_in)).astype(dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv over time. xbc: [B,S,C]; w: [K,C].
    Returns (out [B,S,C], new_state [B,K-1,C])."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([state, xbc], axis=1)            # [B, S+K-1, C]
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else state
    return jax.nn.silu(out + b[None, None, :]), new_state


def ssd_chunked(xh, dt, A, B_, C_, *, chunk: int, unroll: bool = False,
                ssm_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    xh: [B,S,nh,hd]  dt: [B,S,nh] (post-softplus)  A: [nh] (negative)
    B_, C_: [B,S,N].  Returns (y [B,S,nh,hd], final_state [B,nh,hd,N]).
    """
    Bb, S, nh, hd = xh.shape
    N = B_.shape[-1]
    L = min(chunk, S)
    S_orig = S
    if S % L:       # pad with dt=0 steps: decay=1, input weight=0 -> state-neutral
        pad = L - S % L
        pt = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, dt, B_, C_ = pt(xh), pt(dt), pt(B_), pt(C_)
        S += pad
    nc = S // L

    da = (dt * A[None, None, :]).astype(jnp.float32)      # [B,S,nh] (<=0)
    xb = (xh * dt[..., None]).astype(jnp.float32)         # dt-weighted input
    rs = lambda a: a.reshape(Bb, nc, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    da_c, xb_c = rs(da), rs(xb)
    B_c, C_c = rs(B_.astype(jnp.float32)), rs(C_.astype(jnp.float32))
    seg = jnp.cumsum(da_c, axis=2)                        # [nc,B,L,nh] inclusive

    if ssm_state is None:
        ssm_state = jnp.zeros((Bb, nh, hd, N), jnp.float32)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(S_prev, xs):
        xb_i, B_i, C_i, seg_i, da_i = xs                  # [B,L,...]
        CB = jnp.einsum("bin,bjn->bij", C_i, B_i)         # [B,L,L]
        # clamp the (masked-out) upper triangle to exponent<=0: exact on the
        # used triangle, and prevents inf*0 -> NaN in the backward pass
        expo = jnp.minimum(seg_i[:, :, None, :] - seg_i[:, None, :, :], 0.0)
        dec = jnp.exp(expo)                               # [B,L,L,nh]
        att = CB[..., None] * jnp.where(tri[None, :, :, None], dec, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", att, xb_i)      # intra-chunk
        y = y + jnp.einsum("bin,bhpn->bihp", C_i, S_prev) * jnp.exp(seg_i)[..., None]
        tot = seg_i[:, -1, :]                              # [B,nh]
        w_in = jnp.exp(tot[:, None, :] - seg_i)            # [B,L,nh] (<=0 exp)
        S_new = (jnp.exp(tot)[:, :, None, None] * S_prev
                 + jnp.einsum("bjhp,bjn,bjh->bhpn", xb_i, B_i, w_in))
        return S_new, y

    if unroll:
        ys = []
        for i in range(nc):
            ssm_state, y = step(ssm_state, jax.tree.map(lambda a: a[i],
                                                        (xb_c, B_c, C_c, seg, da_c)))
            ys.append(y)
        y = jnp.stack(ys)
    else:
        ssm_state, y = jax.lax.scan(step, ssm_state, (xb_c, B_c, C_c, seg, da_c))
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bb, S, nh, hd)
    return y[:, :S_orig], ssm_state


def mamba_block(p, x, cfg, *, conv_state=None, ssm_state=None, unroll=False,
                hetero_ctx=None):
    """x: [B,S,D] -> (y, new_conv_state, new_ssm_state)."""
    s = cfg.ssm
    d_in, nh, conv_dim = _dims(cfg)
    B, S, D = x.shape
    mm = hetero_ctx.matmul if hetero_ctx is not None else (
        lambda a, b, name=None: a @ b)

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = mm(h, p["in_proj"], name="in_proj")
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, B_, C_ = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, nh, s.head_dim)
    y, new_ssm = ssd_chunked(xh, dt, A, B_, C_, chunk=s.chunk, unroll=unroll,
                             ssm_state=ssm_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return (hidden_constraint(x + mm(y, p["out_proj"], name="out_proj")),
            new_conv, new_ssm)


def mamba_decode_step(p, x, cfg, conv_state, ssm_state, hetero_ctx=None):
    """Exact single-step recurrence. x: [B,1,D]."""
    s = cfg.ssm
    d_in, nh, conv_dim = _dims(cfg)
    B = x.shape[0]
    mm = hetero_ctx.matmul if hetero_ctx is not None else (
        lambda a, b, name=None: a @ b)

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = mm(h, p["in_proj"], name="in_proj")
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, B_, C_ = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])[:, 0]
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, nh, s.head_dim).astype(jnp.float32)
    da = jnp.exp(dt * A[None, :])                          # [B,nh]
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, B_[:, 0].astype(jnp.float32), dt)
    new_ssm = da[:, :, None, None] * ssm_state + upd
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), new_ssm)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return x + mm(y, p["out_proj"], name="out_proj"), new_conv, new_ssm


# ----------------------------------------------------------- hybrid (zamba2) --

def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, v = cfg.d_model, cfg.vocab_size
    k_emb, k_m, k_a, k_f, k_head = jax.random.split(key, 5)
    params = {
        "embed": (jax.random.normal(k_emb, (v, d)) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), dt),
        "head": (jax.random.normal(k_head, (d, v)) / math.sqrt(d)).astype(dt),
        # ONE shared attention+ffn block (zamba2)
        "shared": {
            "attn_norm": jnp.ones((d,), dt),
            "attn": init_attention(k_a, cfg),
            "ffn_norm": jnp.ones((d,), dt),
            "ffn": init_swiglu(k_f, d, cfg.d_ff, cfg.param_dtype),
        },
    }
    mkeys = jax.random.split(k_m, cfg.n_layers)
    params["mamba"] = jax.vmap(lambda k: init_mamba_block(k, cfg))(mkeys)
    return params


def _n_attn(cfg) -> int:
    return cfg.n_layers // cfg.ssm.attn_every


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    d_in, nh, conv_dim = _dims(cfg)
    s = cfg.ssm
    return {
        "k": jnp.zeros((_n_attn(cfg), batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((_n_attn(cfg), batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "conv": jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, nh, s.head_dim, s.d_state),
                         jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }


def _shared_block(sp, x, cfg, *, positions, kv, cache_index, unroll, hetero_ctx):
    h = rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    a, nkv = attention(sp["attn"], h, cfg, positions=positions, cache=kv,
                       cache_index=cache_index, unroll=unroll,
                       hetero_ctx=hetero_ctx)
    x = x + a
    h = rms_norm(x, sp["ffn_norm"], cfg.norm_eps)
    return hidden_constraint(x + swiglu(sp["ffn"], h, hetero_ctx=hetero_ctx)), nkv


def _run(params, x, cfg, *, positions, cache=None, cache_index=None,
         unroll=False, decode=False, hetero_ctx=None):
    """Period structure: ``attn_every`` mamba layers then the shared block."""
    ae = cfg.ssm.attn_every
    np_ = _n_attn(cfg)
    assert cfg.n_layers % ae == 0
    reshape_p = lambda a: a.reshape(np_, ae, *a.shape[1:])
    mparams = jax.tree.map(reshape_p, params["mamba"])

    def period(x, pp, kv, conv_s, ssm_s):
        new_conv, new_ssm = [], []
        for j in range(ae):
            lp = jax.tree.map(lambda a: a[j], pp)
            cs = None if conv_s is None else conv_s[j]
            ss = None if ssm_s is None else ssm_s[j]
            if decode:
                x, nc, ns = mamba_decode_step(lp, x, cfg, cs, ss,
                                              hetero_ctx=hetero_ctx)
            else:
                x, nc, ns = mamba_block(lp, x, cfg, conv_state=cs, ssm_state=ss,
                                        unroll=unroll, hetero_ctx=hetero_ctx)
            new_conv.append(nc); new_ssm.append(ns)
        x, nkv = _shared_block(params["shared"], x, cfg, positions=positions,
                               kv=kv, cache_index=cache_index, unroll=unroll,
                               hetero_ctx=hetero_ctx)
        return x, nkv, jnp.stack(new_conv), jnp.stack(new_ssm)

    if cache is None:   # training: no state tracking
        if unroll:
            for i in range(np_):
                pp = jax.tree.map(lambda a: a[i], mparams)
                x, _, _, _ = period(x, pp, None, None, None)
            return x, None
        def stepf(x, pp):
            x, _, _, _ = period(x, pp, None, None, None)
            return x, None
        body = stepf
        if cfg.remat:
            from .layers import remat_policy_of
            body = jax.checkpoint(stepf, policy=remat_policy_of(cfg))
        x, _ = jax.lax.scan(body, x, mparams)
        return x, None

    conv_c = jax.tree.map(reshape_p, cache["conv"])
    ssm_c = jax.tree.map(reshape_p, cache["ssm"])
    if unroll:
        ks, vs, convs, ssms = [], [], [], []
        for i in range(np_):
            pp = jax.tree.map(lambda a: a[i], mparams)
            kv = {"k": cache["k"][i], "v": cache["v"][i]}
            x, nkv, nc, ns = period(x, pp, kv, conv_c[i], ssm_c[i])
            ks.append(nkv["k"]); vs.append(nkv["v"]); convs.append(nc); ssms.append(ns)
        new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                     "conv": jnp.concatenate(convs), "ssm": jnp.concatenate(ssms)}
        return x, new_cache

    def stepc(x, xs):
        pp, k_l, v_l, cv, ss = xs
        x, nkv, nc, ns = period(x, pp, {"k": k_l, "v": v_l}, cv, ss)
        return x, (nkv["k"], nkv["v"], nc, ns)

    x, (nk, nv, nconv, nssm) = jax.lax.scan(
        stepc, x, (mparams, cache["k"], cache["v"], conv_c, ssm_c))
    new_cache = {"k": nk, "v": nv,
                 "conv": nconv.reshape(cfg.n_layers, *nconv.shape[2:]),
                 "ssm": nssm.reshape(cfg.n_layers, *nssm.shape[2:])}
    return x, new_cache


def loss_fn(params, inputs, targets, cfg, *, unroll=False):
    S = inputs.shape[1]
    x = params["embed"][inputs].astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _ = _run(params, x, cfg, positions=positions, unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = chunked_ce_loss(params["head"], x, targets, chunk=cfg.loss_chunk,
                         unroll=unroll)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, tokens, cache, cfg, *, start_index=0, unroll=False,
            hetero_ctx=None):
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    positions = start_index + jnp.arange(S, dtype=jnp.int32)
    x, nc = _run(params, x, cfg, positions=positions, cache=cache,
                 cache_index=start_index, unroll=unroll, hetero_ctx=hetero_ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1:, :] @ params["head"]).astype(jnp.float32)
    nc["index"] = jnp.asarray(start_index + S, jnp.int32)
    return logits, nc


def decode_step(params, token, cache, cfg, *, unroll=False, hetero_ctx=None):
    idx = cache["index"]
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.full((1,), idx, jnp.int32)
    x, nc = _run(params, x, cfg, positions=positions, cache=cache,
                 cache_index=idx, unroll=unroll, decode=True,
                 hetero_ctx=hetero_ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    nc["index"] = idx + 1
    return logits, nc
