"""Weight quantization for serving: fp params -> QuantWeight-carrying params.

``quantize_params`` rewrites the dense-transformer matmul sites (attention
q/k/v/o, SwiGLU gate/up/down, and the untied LM head) into
:class:`repro.core.partition.QuantWeight` containers — int8 or packed-int4
codes plus per-output-channel scales — while embeddings and norms stay fp.
Because QuantWeight is a pytree node whose arrays keep the stacked ``[L,...]``
layer axis, the quantized params thread through every existing inference
path (``lax.scan`` layer stacks, jit, donation) unchanged; the HeteroCtx
dispatches the in-VMEM-dequant MXU kernels at quantized sites and the
plan-free fallback dequantizes before the matmul, so any execution schedule
sees the SAME dequantized weight values (token-identity across arms).

Tied-embedding models (e.g. smollm-135m) keep their LM head fp: the head is
the embedding transpose, and quantizing it would also perturb the input
embeddings — a different (activation) quantization problem than the paper's
weight-only W4A16 stance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.partition import QuantWeight
from repro.kernels.hetero_matmul.ops import (quantize_weight,
                                             quantize_weight_int4)

WEIGHT_FORMATS = ("int8", "w4a16")

# the partitionable matmul sites quantization covers, by param subtree
_ATTN_SITES = ("wq", "wk", "wv", "wo")
_FFN_SITES = ("w_gate", "w_up", "w_down")


def _quantize_leaf(w: jax.Array, fmt: str) -> QuantWeight:
    """Quantize one (possibly layer-stacked) weight: [K, N] or [L, K, N]."""
    k = w.shape[-2]
    qfn = quantize_weight if fmt == "int8" else quantize_weight_int4
    if w.ndim == 3:
        wq, scale = jax.vmap(qfn)(w)
    else:
        wq, scale = qfn(w)
    return QuantWeight(wq, scale, fmt, k)


def quantize_params(params: dict, cfg, fmt: str) -> dict:
    """Return a copy of ``params`` with every dense matmul site quantized to
    ``fmt`` ('int8' or 'w4a16'). Embeddings, norms, and a tied LM head stay
    in the original dtype."""
    if fmt not in WEIGHT_FORMATS:
        raise ValueError(f"unsupported weight quant format {fmt!r}; "
                         f"expected one of {WEIGHT_FORMATS}")
    if cfg.family != "dense":
        raise NotImplementedError(
            f"weight quantization covers the dense transformer family only "
            f"(got {cfg.family!r})")
    if cfg.moe:
        raise NotImplementedError("MoE expert weights are not quantized yet")

    out = dict(params)
    layers = dict(params["layers"])
    attn = dict(layers["attn"])
    for site in _ATTN_SITES:
        attn[site] = _quantize_leaf(attn[site], fmt)
    layers["attn"] = attn
    ffn = dict(layers["ffn"])
    for site in _FFN_SITES:
        ffn[site] = _quantize_leaf(ffn[site], fmt)
    layers["ffn"] = ffn
    out["layers"] = layers
    if "head" in params:          # untied head is a partitionable site too
        out["head"] = _quantize_leaf(params["head"], fmt)
    return out


def dequantize_params(params: dict) -> dict:
    """Expand every QuantWeight back to an fp array — the dequantize-then-fp
    reference arm the conformance tier compares quantized execution against."""
    return jax.tree.map(
        lambda w: w.dequant(jnp.float32) if isinstance(w, QuantWeight) else w,
        params, is_leaf=lambda w: isinstance(w, QuantWeight))


def score_nll(model, params, tokens: jax.Array) -> float:
    """Mean next-token NLL (nats/token) of a fixed token set, teacher-forced
    — the mini-eval behind the perplexity-drift regression test and
    benchmarks/bench_quant.py. tokens: [B, S+1] int32."""
    _, metrics = model.loss(params, tokens[:, :-1], tokens[:, 1:])
    return float(metrics["ce"])
