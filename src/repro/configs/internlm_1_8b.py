"""internlm-1.8b [dense]: paper's own small eval model (InternLM2-1.8B proxy):
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544. [hf:internlm/internlm2-1_8b]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm-1.8b", family="dense", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab_size=92544, rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="internlm-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256,
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
