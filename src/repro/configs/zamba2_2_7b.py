"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Mamba2 backbone + shared attention blocks every 6 layers.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256,
                  attn_every=6),
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32,
                  attn_every=2),
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
