"""Config system: model configs, shape specs, and the assigned (arch x shape) grid.

Every architecture assigned to this paper gets a module in ``repro/configs/``
exporting ``CONFIG`` (the exact published config) and ``SMOKE`` (a reduced config
of the same family for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0            # total shared-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # dispatch group size (tokens): capacity is PER GROUP, so the dispatch/
    # combine one-hot tensors stay O(group x E x C_g) instead of O(T x E x C)
    # — the difference between a 507GB/device and a fits-in-HBM train step
    # for dbrx-132b (EXPERIMENTS.md §Perf moe/i1).
    group_size: int = 1024


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings, used by hybrid archs."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                # SSD chunk length
    attn_every: int = 6             # hybrid: a (shared) attention block every N layers


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64            # rank of the data-dependent decay LoRA
    chunk: int = 256                # chunked-recurrence length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    encoder_only: bool = False      # hubert: bidirectional, no KV cache / decode
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_block_q: int = 512         # blockwise-attention tile sizes (pure-JAX path)
    attn_block_k: int = 1024
    loss_chunk: int = 512           # sequence chunk for the CE loss (avoids T x V logits)
    remat: bool = True
    # "nothing" = full recompute (min memory); "dots" = keep matmul outputs
    # (no recompute of MXU work in backward; costs activation memory)
    remat_policy: str = "nothing"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.rwkv is not None

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_params(self) -> int:
        """Total parameter count (analytic)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.rwkv is not None:
            # token-mix: r,k,v,g,o projections + decay lora; channel-mix: 2 mats
            per_layer = 5 * d * d + 2 * self.rwkv.decay_lora * d + d * self.d_ff * 2
            return emb + self.n_layers * per_layer
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        dense_ffn = 3 * d * self.d_ff
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            mamba = d * (2 * d_in + 2 * s.d_state * nh // (d_in // s.head_dim) ) if False else (
                d * (2 * d_in) + d * (2 * s.d_state) * 0 +  # placeholder, refined below
                0)
            # in_proj: d -> (2*d_in + 2*n_groups*d_state + n_heads); use n_groups=1
            in_proj = d * (2 * d_in + 2 * s.d_state + nh)
            out_proj = d_in * d
            conv = d_in * s.d_conv
            mamba = in_proj + out_proj + conv + nh  # + A,dt biases
            n_attn = self.n_layers // s.attn_every
            # shared attention block: ONE copy of (attn + ffn)
            shared = attn + dense_ffn
            return emb + self.n_layers * mamba + shared
        per_layer = attn + (0 if self.moe else dense_ffn)
        if self.moe:
            m = self.moe
            per_layer += d * m.n_experts  # router
            per_layer += m.n_experts * 3 * d * m.d_ff_expert
            if m.d_ff_shared:
                per_layer += 3 * d * m.d_ff_shared + d  # shared expert + gate
        return emb + self.n_layers * per_layer

    @property
    def n_params_active(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if not self.moe:
            return self.n_params
        m = self.moe
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return self.n_params - self.n_layers * inactive


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


# The four assigned input-shape cells (identical for every LM arch).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_is_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Returns (supported, reason-if-not) for an (arch x shape) cell.

    Skips mandated by the assignment:
      - ``long_500k`` needs sub-quadratic attention -> SSM/hybrid only.
      - encoder-only archs have no decode step.
    """
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not (cfg.ssm or cfg.rwkv):
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
