"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504.
Encoder-only (bidirectional, no decode); the conv waveform frontend is a STUB --
``input_specs()`` provides precomputed frame embeddings. [arXiv:2106.07447; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280, n_heads=16,
    n_kv_heads=16, d_ff=5120, vocab_size=504, encoder_only=True,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=64, encoder_only=True,
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
