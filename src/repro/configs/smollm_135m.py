"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576, n_heads=9,
    n_kv_heads=3, d_ff=1536, vocab_size=49152, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense", n_layers=2, d_model=48, n_heads=3,
    n_kv_heads=1, d_ff=128, vocab_size=256, tie_embeddings=True,
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
