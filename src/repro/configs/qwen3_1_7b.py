"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=6144, vocab_size=151936, qk_norm=True,
    rope_theta=1000000.0, d_head=128,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, qk_norm=True, d_head=16,
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
