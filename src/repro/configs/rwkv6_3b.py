"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.
Finch: data-dependent decay linear recurrence. [arXiv:2404.05892; hf]"""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560, n_heads=40,
    n_kv_heads=40, d_ff=8960, vocab_size=65536,
    # chunk=64: the intra-chunk pairwise decay tensor streams S*L*H*hd
    # elements per layer, linear in L; 256->64 cuts the train-cell
    # memory term ~4x at equal math (EXPERIMENTS.md §Perf rwkv/i1).
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=64),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256,
    rwkv=RWKVConfig(head_dim=16, decay_lora=16, chunk=32),
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
