"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early-fusion VLM: VQ image tokens share the text vocab; the modality frontend is a
STUB -- ``input_specs()`` provides precomputed patch/VQ token embeddings.
[arXiv:2405.09818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab_size=65536, qk_norm=True,
)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, qk_norm=True,
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
