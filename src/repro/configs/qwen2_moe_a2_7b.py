"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE 60 routed top-4 + 4 shared experts. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab_size=151936, rope_theta=1000000.0,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4, d_ff_shared=5632),
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=48, vocab_size=256,
    moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=48,
                  n_shared_experts=2, d_ff_shared=96),
    attn_block_q=32, attn_block_k=32, loss_chunk=32,
)
