"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

Assigned architectures (public-literature configs):
  dbrx-132b qwen2-moe-a2.7b smollm-135m llama3-8b tinyllama-1.1b qwen3-1.7b
  chameleon-34b zamba2-2.7b rwkv6-3b hubert-xlarge
plus the paper's own evaluation models (llama-8b alias, internlm-1.8b proxy).
"""
from __future__ import annotations

import importlib

from .base import ModelConfig, MoEConfig, SSMConfig, RWKVConfig, ShapeSpec, SHAPES, cell_is_supported

_ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "smollm-135m": "smollm_135m",
    "llama3-8b": "llama3_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen3-1.7b": "qwen3_1_7b",
    "chameleon-34b": "chameleon_34b",
    "zamba2-2.7b": "zamba2_2_7b",
    "rwkv6-3b": "rwkv6_3b",
    "hubert-xlarge": "hubert_xlarge",
    # paper's own models (for the paper-faithful benchmarks)
    "internlm-1.8b": "internlm_1_8b",
}

ARCHS = tuple(_ARCH_MODULES)
ASSIGNED_ARCHS = ARCHS[:10]


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "RWKVConfig", "ShapeSpec", "SHAPES",
    "cell_is_supported", "get_config", "get_smoke_config", "ARCHS", "ASSIGNED_ARCHS",
]
