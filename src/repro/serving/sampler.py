"""Token sampling: greedy / temperature / top-k / top-p (fully jittable)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => off
    top_p: float = 1.0            # 1 => off


def filter_logits(logits: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """Temperature-scale then mask logits outside the top-k / top-p support
    to -inf. logits [B, V] -> [B, V]. Applied before categorical sampling;
    split out so tests can assert the support sets directly."""
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def sample(logits: jax.Array, rng, cfg: SamplerConfig) -> jax.Array:
    """logits [B, V] -> tokens [B] int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, filter_logits(logits, cfg),
                                  axis=-1).astype(jnp.int32)
