"""Token sampling — greedy / temperature / top-k / top-p — plus the
speculative-decoding acceptance rule (``greedy_verify``). Fully jittable."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => off
    top_p: float = 1.0            # 1 => off


def filter_logits(logits: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """Temperature-scale then mask logits outside the top-k / top-p support
    to -inf. logits [B, V] -> [B, V]. Applied before categorical sampling;
    split out so tests can assert the support sets directly.

    Temperature 0 means greedy (``sample`` argmaxes without calling here),
    so a direct call must not divide by it — scaling only applies when
    ``temperature > 0``. ``top_k`` is clamped to the vocab size: k >= V
    keeps every token rather than indexing out of range."""
    if cfg.temperature > 0.0:
        logits = logits / cfg.temperature
    if cfg.top_k:
        k = min(cfg.top_k, logits.shape[-1])
        kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def sample(logits: jax.Array, rng, cfg: SamplerConfig) -> jax.Array:
    """logits [B, V] -> tokens [B] int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, filter_logits(logits, cfg),
                                  axis=-1).astype(jnp.int32)


def greedy_verify(draft_tokens: jax.Array, target_logits: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Greedy speculative-decoding acceptance rule (lossless: the emitted
    stream is exactly what per-token greedy decoding of the target would
    produce, whatever the drafts were).

    draft_tokens: [B, K] int32 — each lane's K drafted tokens.
    target_logits: [B, K+1, V] — the target model's per-position logits from
    one ``paged_verify`` dispatch; position ``j`` scores the token FOLLOWING
    the j-th appended token (the lane's pending token, then the drafts).

    A draft is accepted while it matches the target's greedy choice at its
    position; the first mismatch position contributes the target's own
    (correction) token, and full acceptance contributes the free bonus
    token after the last draft — so every round emits between 1 and K+1
    tokens. Returns ``(emitted [B, K+1] int32, n_emitted [B] int32)``:
    ``emitted[b, :n_emitted[b]]`` is the lane's verified token stream for
    the round (slots past it hold the target's greedy tokens, which callers
    must ignore). Fully jittable; lives here so the scheduler, the
    single-stream SpecDecoder and the tests all share one verification
    implementation.
    """
    greedy = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)  # [B, K+1]
    match = draft_tokens == greedy[:, :-1]                         # [B, K]
    accepted = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    slots = jnp.arange(greedy.shape[1], dtype=jnp.int32)[None, :]
    drafts_pad = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    emitted = jnp.where(slots < accepted[:, None], drafts_pad, greedy)
    return emitted, accepted + 1
