"""Device layout objects: serving-state placement, decoupled from logic.

ROADMAP item 3a's load-bearing refactor: ``PagedBatcher`` / ``PagedKVCache``
host-side bookkeeping (block tables, refcounts, prefix-cache hash chains,
admission accounting) is device-agnostic — it reasons about *logical* block
ids. What varies across deployments is only where the arrays live. A layout
object owns exactly that:

  * ``DeviceLayout``    — the single-device identity layout (placement is a
    no-op, the step functions are the model's own paged entry points).
  * ``MeshLayout(mesh)`` — head-wise tensor parallelism over the mesh's
    ``model`` axis: weights and the paged KV pool shard, host bookkeeping
    stays replicated, and the four paged inference paths (``paged_prefill``,
    ``paged_decode_step`` — the fused-window scan body — ``mixed_step`` and
    ``paged_verify``) run under ``shard_map``.

Sharding plan (TP = model-axis size):

  shards over ``model``                          replicates
  ---------------------------------------------  -------------------------
  wq/wk/wv          output cols (heads local)    embed table
  wo                output cols (d_model/TP)     all norms
  w_gate/w_up       output cols (d_ff/TP)        int8 pool scale planes
  w_down            output cols (d_model/TP)     tied head (via embed)
  head (untied)     output cols (vocab/TP)       block tables / lengths
  KV pool k/v       axis 3 (KV heads local)      draft-lane params (spec)

Every sharded matrix splits on its OUTPUT axis, never the contraction axis:
each shard computes full-depth reductions for its slice of the output
columns and a tiled ``all_gather`` concatenates the slices in shard order.
That makes TP an execution schedule, never a numerics change — greedy token
streams are bit-identical to the single-device batcher (a row-parallel
psum-of-partials would reassociate the reduction and drift at ULP level).
Quantized sites shard the same way: ``QuantWeight`` codes and their
per-output-channel scales both split along N, so w4a16's K-axis nibble
packing is never cut. Per-step collectives (decode, B lanes, d = d_model):

  * 2 all-gathers of [B, 1, d] per layer (head concat + wo output concat)
  * 2 all-gathers of [B, 1, d_ff] / [B, 1, d] per layer (FFN hidden/output)
  * 1 all-gather of [B, 1, vocab] for untied LM-head logits
  * int8 pool only: 1 pmax of [tokens] per layer (global amax for the slot
    scales — max-of-maxes is exact, so codes match the single-device pool
    bit for bit)
"""
from __future__ import annotations

import re
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.partition import QuantWeight
from repro.distributed.compat import shard_map
from repro.distributed.sharding import sanitize_spec
from repro.models import transformer

TP_AXIS = "model"

# param paths whose LAST axis is an output-channel axis sharded over TP
_COL_SHARDED = re.compile(r"(attn/(wq|wk|wv|wo)|ffn/(w_gate|w_up|w_down))$")


class DeviceLayout:
    """Single-device identity layout."""

    mesh = None
    tp = 1

    def place_params(self, params):
        return params

    def place_pool(self, pool):
        return pool

    def step_fns(self, model, params):
        """The model's own paged entry points, unchanged."""
        return {"paged_prefill": model.paged_prefill,
                "paged_decode_step": model.paged_decode_step,
                "mixed_step": model.mixed_step,
                "paged_verify": model.paged_verify}


class MeshLayout(DeviceLayout):
    """Head-wise tensor-parallel layout over ``mesh``'s ``model`` axis."""

    def __init__(self, cfg, mesh):
        if "model" not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
        tp = mesh.shape[TP_AXIS]
        if cfg.moe is not None or cfg.ssm is not None or cfg.rwkv is not None:
            raise ValueError("tensor-parallel serving supports the dense "
                             "transformer family only")
        for dim, name in ((cfg.n_heads, "n_heads"),
                          (cfg.n_kv_heads, "n_kv_heads"),
                          (cfg.d_model, "d_model"),
                          (cfg.d_ff, "d_ff")):
            if dim % tp:
                raise ValueError(
                    f"cfg.{name}={dim} is not divisible by the model-axis "
                    f"size {tp}; pick a TP width that divides it")
        if not cfg.tie_embeddings and cfg.vocab_size % tp:
            raise ValueError(
                f"untied head: vocab_size={cfg.vocab_size} is not divisible "
                f"by the model-axis size {tp}")
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp
        # the shard-local view: each shard runs the UNCHANGED transformer
        # code over its own heads. head_dim is derived from d_model/n_heads
        # when d_head is 0, so pin it before halving the head counts.
        self.cfg_local = cfg.with_(n_heads=cfg.n_heads // tp,
                                   n_kv_heads=cfg.n_kv_heads // tp,
                                   d_head=cfg.head_dim)

    # ------------------------------------------------------------- specs --

    def _last_axis(self, ndim: int) -> P:
        return P(*([None] * (ndim - 1)), TP_AXIS)

    def param_specs(self, params) -> Any:
        """Params-shaped pytree of PartitionSpec (QuantWeight leaves map to
        QuantWeight nodes holding their children's specs, so the spec tree
        flattens 1:1 with the params tree)."""
        def spec_for(path, leaf):
            parts = [p.key for p in path
                     if isinstance(p, jax.tree_util.DictKey)]
            s = "/".join(parts)
            col = (_COL_SHARDED.search(s) is not None
                   or (s == "head" and not self.cfg.tie_embeddings))
            if isinstance(leaf, QuantWeight):
                if not col:
                    return QuantWeight(P(), P(), leaf.fmt, leaf.k)
                return QuantWeight(self._last_axis(leaf.wq.ndim),
                                   self._last_axis(leaf.scale.ndim),
                                   leaf.fmt, leaf.k)
            return self._last_axis(leaf.ndim) if col else P()

        return jax.tree_util.tree_map_with_path(
            spec_for, params, is_leaf=lambda x: isinstance(x, QuantWeight))

    def pool_specs(self, pool) -> dict:
        """k/v: [L, NB, BS, Hkv, D] with KV heads (axis 3) over ``model``;
        int8 scale planes [L, NB, BS] replicate (one scalar per slot covers
        ALL heads, so every shard must hold it)."""
        specs = {}
        for key, leaf in pool.items():
            if key in ("k", "v"):
                spec = P(None, None, None, TP_AXIS, None)
                dropped: list = []
                sanitize_spec(spec, leaf.shape, self.mesh, dropped=dropped)
                if 3 in dropped:
                    raise ValueError(
                        f"KV-head dim of pool[{key!r}] (size {leaf.shape[3]})"
                        f" did not shard over the {self.tp}-wide model axis —"
                        " the pool would silently replicate")
                specs[key] = spec
            else:
                specs[key] = P()
        return specs

    # --------------------------------------------------------- placement --

    def place_params(self, params):
        specs = self.param_specs(params)
        return jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(self.mesh, s)),
            params, specs)

    def place_pool(self, pool):
        specs = self.pool_specs(pool)
        return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in pool.items()}

    # -------------------------------------------------------- step fns --

    def step_fns(self, model, params) -> dict:
        """shard_map-wrapped variants of the four paged inference paths,
        signature-compatible with the model's own (``hetero_ctx`` is
        accepted for interface parity but must be None — the hetero engine
        and the mesh are separate axes of the machine). The returned
        callables have stable identity: callers may bake them into jitted
        graphs as static arguments (core/sync.py fused windows)."""
        cfg_l = self.cfg_local
        pspecs = self.param_specs(params)
        rep = P()

        def _pool_specs(pool):
            return self.pool_specs(pool)

        def _sm(inner, in_specs, out_specs):
            return shard_map(inner, mesh=self.mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

        # pool spec structure depends on kv_quant; build per-call-signature
        # wrappers lazily on first use and cache them (stable identity).
        cache: dict = {}

        def _cached(key, build):
            if key not in cache:
                cache[key] = build()
            return cache[key]

        def paged_prefill(params, tokens, pool, *, block_table,
                          start_index=0, unroll=False, hetero_ctx=None):
            _no_ctx(hetero_ctx)
            ps = _pool_specs(pool)

            def build():
                def inner(params, tokens, pool, block_table, start_index):
                    return transformer.paged_prefill(
                        params, tokens, pool, cfg_l, block_table=block_table,
                        start_index=start_index, tp_axis=TP_AXIS)
                return _sm(inner, (pspecs, rep, ps, rep, rep), (rep, ps))

            return _cached(("prefill", tuple(sorted(ps))), build)(
                params, tokens, pool, block_table,
                jnp.asarray(start_index, jnp.int32))

        def paged_decode_step(params, token, pool, *, block_tables, lengths,
                              unroll=False, hetero_ctx=None):
            _no_ctx(hetero_ctx)
            ps = _pool_specs(pool)

            def build():
                def inner(params, token, pool, block_tables, lengths):
                    return transformer.paged_decode_step(
                        params, token, pool, cfg_l,
                        block_tables=block_tables, lengths=lengths,
                        tp_axis=TP_AXIS)
                return _sm(inner, (pspecs, rep, ps, rep, rep), (rep, ps))

            return _cached(("decode", tuple(sorted(ps))), build)(
                params, token, pool, block_tables, lengths)

        def mixed_step(params, decode_tokens, prefill_tokens, pool, *,
                       decode_tables, decode_lengths, prefill_table,
                       prefill_start=0, unroll=False, hetero_ctx=None):
            _no_ctx(hetero_ctx)
            ps = _pool_specs(pool)

            def build():
                def inner(params, dt, pt, pool, dtab, dlen, ptab, pstart):
                    return transformer.mixed_step(
                        params, dt, pt, pool, cfg_l, decode_tables=dtab,
                        decode_lengths=dlen, prefill_table=ptab,
                        prefill_start=pstart, tp_axis=TP_AXIS)
                return _sm(inner, (pspecs, rep, rep, ps, rep, rep, rep, rep),
                           (rep, rep, ps))

            return _cached(("mixed", tuple(sorted(ps))), build)(
                params, decode_tokens, prefill_tokens, pool, decode_tables,
                decode_lengths, prefill_table,
                jnp.asarray(prefill_start, jnp.int32))

        def paged_verify(params, tokens, pool, *, block_table, start_index,
                         unroll=False, hetero_ctx=None):
            _no_ctx(hetero_ctx)
            ps = _pool_specs(pool)

            def build():
                def inner(params, tokens, pool, block_table, start_index):
                    return transformer.paged_verify(
                        params, tokens, pool, cfg_l, block_table=block_table,
                        start_index=start_index, tp_axis=TP_AXIS)
                return _sm(inner, (pspecs, rep, ps, rep, rep), (rep, ps))

            return _cached(("verify", tuple(sorted(ps))), build)(
                params, tokens, pool, block_table,
                jnp.asarray(start_index, jnp.int32))

        return {"paged_prefill": paged_prefill,
                "paged_decode_step": paged_decode_step,
                "mixed_step": mixed_step,
                "paged_verify": paged_verify}


def _no_ctx(hetero_ctx):
    if hetero_ctx is not None:
        raise ValueError("tensor-parallel serving does not compose with a "
                         "HeteroCtx engine mode (engine_mode must be None "
                         "when a mesh is given)")


def make_layout(cfg, mesh) -> DeviceLayout:
    return DeviceLayout() if mesh is None else MeshLayout(cfg, mesh)
