"""Continuous-batching serving schedulers: dense slots and paged blocks.

``ContinuousBatcher`` (the dense baseline) runs slot-based continuous
batching over a preallocated ``[max_batch, max_len]`` KV cache: requests
join free slots, prefill runs per-request as bucket-chunked pieces (the
engine's activation-centric strategy applied at the scheduler level),
decode steps run batched across all active slots with PER-SLOT cache
indices. Finished slots free immediately and the queue backfills
(orca-style iteration-level scheduling).

``PagedBatcher`` rebases the same loop on the paged KV pool
(serving/paged_cache.py): admission is gated by FREE BLOCKS rather than
fixed slots, so many short requests can share the memory one long request
used to reserve under the dense scheme, and the queue backfills at block
granularity — the KV-capacity lever the paper's unified-memory analysis
(§3, §4.2) identifies as the mobile serving bottleneck.

The paged batcher additionally fuses the HeteroInfer engine into the
serving path (docs/heterogeneous-execution.md):

  * ``sync='device'`` — fast-sync decode (§4.3): one jitted ``lax.scan``
    runs a ``window`` of paged decode steps per dispatch, so the scheduler
    pays one host round-trip per WINDOW instead of per token (the paper's
    ~400us-clFinish-per-kernel problem, at serving batch widths).
    ``sync='host'`` keeps the per-token host-synced loop as the measurable
    baseline arm.
  * ``engine_mode=...`` — solver-planned prefill (§4.1/§4.2): admission-time
    prefill chunks route every matmul through a ``HeteroCtx`` whose
    ``PartitionSolver`` plan was solved offline for this model, with one
    compiled graph per chunk length ('graphs generated in advance').
  * ``mixed_batch=True`` — stage-parallel mixed batching (§4.1-§4.3 at the
    stage level): each scheduler step coalesces ONE bucket-sized prefill
    chunk of the admitting request with the decode step/window of every
    running lane into a single jitted dispatch (``transformer.mixed_step``
    / the mixed ``paged_decode_window``), sharing one paged-pool write.
    Decode (memory-bound, flexible path) and the prefill chunk
    (compute-bound, aligned MXU path) run concurrently — the SoC's full
    compute AND bandwidth envelopes — so admission stops costing its own
    dispatches and never stalls decode.
  * ``spec=SpecConfig(...)`` — heterogeneous speculative decoding
    (serving/spec.py): each scheduler step becomes one ROUND — the draft
    model proposes K tokens per lane on the flexible path (per-lane draft
    caches, one fused draft dispatch under ``sync='device'``), ONE
    ``paged_verify`` target dispatch scores all lanes' K+1 positions
    through the solver's VERIFY-planned matmuls, greedy acceptance emits
    1..K+1 tokens per lane, and ``PagedKVCache.truncate_to`` reclaims the
    rejected tail block-granularly. Decode's per-token dispatch tax drops
    to per-round; greedy outputs stay bit-identical to the non-spec arms.

Both batchers expose one ``stats() -> dict`` counter snapshot (dispatches,
steps, fusion and speculation counters) — the contract the benches assert
on and ``serve.py --stats`` prints.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model

from .paged_cache import PagedKVCache, SequenceBlocks
from .sampler import SamplerConfig, greedy_verify, sample
from .spec import DraftLanes, SpecConfig
from .trace import NULL_TRACER


def bucket_chunks(S: int, buckets: tuple) -> list[int]:
    """Greedy bucket decomposition of a prompt length: aligned chunks take
    the static fast path, the ragged tail takes the flexible path. Shared
    by the dense and paged batchers so both chunk prefill identically."""
    chunks, rem = [], S
    for bk in sorted(buckets, reverse=True):
        while rem >= bk:
            chunks.append(bk)
            rem -= bk
    if rem:
        chunks.append(rem)
    return chunks


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    output: list = field(default_factory=list)
    done: bool = False


def _validate_submit(req: Request, live_rids) -> None:
    """Shared submit guard: an empty prompt has no first token to sample
    (prefill would dispatch a zero-length chunk) and a request id already
    queued or in flight would make two streams indistinguishable — both
    raise here instead of failing obscurely mid-schedule. Finished ids may
    be reused (replay waves and preemption resumes depend on it)."""
    if len(req.prompt) == 0:
        raise ValueError(f"request {req.rid}: empty prompt — a request "
                         "must carry at least one prompt token")
    if req.rid in live_rids:
        raise ValueError(f"request {req.rid}: duplicate id — a request "
                         "with this id is already queued or in flight")


class ContinuousBatcher:
    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 max_len: int = 512, buckets=(64, 128, 256),
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 weight_quant: str | None = None, tracer=None):
        assert cfg.moe is None or True
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.weight_quant = weight_quant
        if weight_quant is not None:
            from repro.models.quant import quantize_params
            self.params = quantize_params(self.params, cfg, weight_quant)
        self.B, self.S = max_batch, max_len
        self.buckets = tuple(sorted(buckets))
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(seed)

        self.cache = self.model.init_cache(batch=max_batch, max_len=max_len)
        self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.queue: list[Request] = []
        self.budget: list[int] = [0] * max_batch
        self.lengths: list[int] = [0] * max_batch   # python-side slot lengths
        self.peak_active = 0           # max concurrent requests observed
        self.decode_dispatches = 0     # batched decode steps issued
        self.decode_steps = 0          # per-slot tokens decoded
        self.prefill_dispatches = 0    # prefill chunk dispatches issued

        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        from repro.models import transformer
        self._prefill_piece = jax.jit(partial(transformer.prefill_slot,
                                              cfg=cfg),
                                      static_argnames=("chunk",),
                                      donate_argnums=(1,))

    @property
    def busy(self) -> bool:
        """Work outstanding: queued requests or occupied slots (same
        contract as ``PagedBatcher.busy`` — what external tick-drivers
        loop on)."""
        return bool(self.queue or any(s is not None for s in self.slots))

    # ------------------------------------------------------------ plumbing --
    def submit(self, req: Request):
        _validate_submit(req, {r.rid for r in self.queue}
                         | {s.rid for s in self.slots if s is not None})
        self.queue.append(req)

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[b] = req
                S = len(req.prompt)
                logits, idx = None, 0
                for c in bucket_chunks(S, self.buckets):
                    piece = jnp.asarray(req.prompt[idx: idx + c], jnp.int32)
                    with self.tracer.dispatch(
                            "prefill_chunk", track="prefill",
                            args={"rid": req.rid, "chunk": c, "start": idx}):
                        logits, self.cache = self._prefill_piece(
                            self.params, self.cache, piece,
                            jnp.asarray(b), jnp.asarray(idx, jnp.int32),
                            chunk=c)
                    self.prefill_dispatches += 1
                    self.tracer.count("prefill_dispatches")
                    idx += c
                self.cache["index"] = self.cache["index"].at[b].set(S)
                self.lengths[b] = S
                self.rng, k = jax.random.split(self.rng)
                first = int(sample(logits[:, -1, :], k, self.sampler)[0])
                req.output.append(first)
                self.budget[b] = req.max_new_tokens - 1
                if self.budget[b] <= 0:     # satisfied at prefill: don't
                    req.done = True         # overproduce a decode token
                    self.slots[b] = None
                    self.lengths[b] = 0

    # ----------------------------------------------------------------- run --
    def step(self):
        """One scheduler tick: admit waiting requests, one batched decode."""
        self._admit()
        active = [b for b in range(self.B) if self.slots[b] is not None]
        self.peak_active = max(self.peak_active, len(active))
        self.tracer.gauge("peak_active", self.peak_active)
        if not active:
            return False
        last = np.zeros((self.B, 1), np.int32)
        for b in active:
            last[b, 0] = self.slots[b].output[-1]
        # decode_step itself advances every slot's index by one
        with self.tracer.dispatch("decode_step", track="decode",
                                  args={"active": len(active)}):
            logits, self.cache = self._decode(self.params,
                                              jnp.asarray(last), self.cache)
        self.decode_dispatches += 1
        self.tracer.count("decode_dispatches")
        self.rng, k = jax.random.split(self.rng)
        toks = np.asarray(sample(logits[:, -1, :], k, self.sampler))
        for b in active:
            req = self.slots[b]
            req.output.append(int(toks[b]))
            self.budget[b] -= 1
            self.lengths[b] += 1
            self.decode_steps += 1
            self.tracer.count("decode_steps")
            if self.budget[b] <= 0 or self.lengths[b] + 1 >= self.S:
                req.done = True
                self.slots[b] = None           # free slot; queue backfills
                self.lengths[b] = 0
        return True

    def stats(self) -> dict:
        """Unified counter snapshot (same contract as ``PagedBatcher.stats``):
        dispatches actually issued vs tokens produced."""
        return {
            "peak_active": self.peak_active,
            "decode_dispatches": self.decode_dispatches,
            "decode_steps": self.decode_steps,
            "prefill_dispatches": self.prefill_dispatches,
            "fused_steps": 0,
            "total_dispatches": (self.decode_dispatches +
                                 self.prefill_dispatches),
        }

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests


# --------------------------------------------------------------- paged ------

@dataclass
class _PagedLane:
    """One decode lane: the request plus its pool bookkeeping."""
    req: Request
    seq: SequenceBlocks
    budget: int = 0


@dataclass
class _Admission:
    """A request whose prefill is in flight under mixed batching: its blocks
    are already reserved, its prompt drains one bucket-sized chunk per
    scheduler step, each chunk fused into that step's decode dispatch."""
    req: Request
    seq: SequenceBlocks
    chunks: list                       # remaining chunk lengths
    idx: int = 0                       # prompt tokens resident so far
    #                                    (cache-hit prefix + prefilled)


class PagedBatcher:
    """Continuous batching over the paged KV pool.

    Admission is by free blocks: a request is admitted when
    ``ceil((len(prompt) + max_new_tokens) / block_size)`` blocks can be
    reserved, regardless of how many other requests are in flight (up to
    ``decode_width`` compiled decode lanes). Prompt blocks are allocated at
    admission; generation blocks are allocated lazily as decode crosses
    block boundaries (drawing on the admission-time reservation, so growth
    never fails mid-flight). Finished requests return their blocks and the
    queue backfills immediately.

    Decode runs as ONE jitted graph of static width ``decode_width``:
    inactive lanes carry a null block table and length 0, sinking their
    writes into the pool's null block. With ``sync='device'`` that graph is
    a fused WINDOW of ``window`` decode steps (core/sync.py
    ``paged_decode_window``): block tables are pre-grown on the host to
    cover the whole window's writes, per-lane budgets/EOS are masked inside
    the scan, and lengths/blocks are reconciled on the host after each
    window — one dispatch per window instead of per token.

    ``engine_mode`` in {'xla', 'mxu', 'hetero-layer', 'hetero-tensor'}
    routes prefill matmuls through the solver-planned HeteroCtx
    (partitioning is an execution schedule, never a numerics change, so
    greedy outputs are identical across engine modes and sync arms).

    ``mixed_batch=True`` turns on stage-parallel mixed batching: admission
    prefill no longer runs as its own dispatches. Instead one request at a
    time holds an ``_Admission`` ticket and each scheduler step fuses its
    next prompt chunk (capped at ``max_prefill_chunk_per_step`` tokens)
    into the decode dispatch of the running lanes — ``model.mixed_step``
    under ``sync='host'``, a chunk-carrying ``paged_decode_window`` under
    ``sync='device'``. Chunks only fall back to standalone prefill
    dispatches when no lane is decoding. Fusion reorders dispatches, never
    math: the two streams touch disjoint pool blocks, so greedy outputs
    stay token-identical to the admit-then-decode arms.

    ``spec=SpecConfig(k=K, draft=...)`` (or just ``spec=K``) turns on
    speculative decoding (serving/spec.py, greedy sampler only): each step
    is one round — K drafts per lane from the draft model's per-lane
    caches, ONE batched ``paged_verify`` target dispatch over every lane's
    pending+draft tokens (the solver's VERIFY site class), greedy
    acceptance, token-level pool rollback via ``truncate_to``. The draft
    loop is host-stepped under ``sync='host'`` and one fused on-device
    scan under ``sync='device'``; the TARGET pays one dispatch per round
    either way, which is the counter the benches compare. Mutually
    exclusive with ``mixed_batch`` (both re-purpose the step loop).

    ``prefix_cache=True`` turns on automatic prefix caching
    (serving/paged_cache.py): closed sequences retire their full blocks
    into a chain-hash-indexed cache instead of freeing them, admission
    shares every consecutively-matching block (refcounted, copy-on-write
    when the hit covers the whole prompt), and prefill runs only the
    uncached suffix — strictly fewer prefill dispatches and fresh-block
    allocations on shared-system-prompt traffic, with greedy outputs
    bit-identical to the cold path (cached KV was computed from the same
    tokens at the same positions). Eviction is LRU over refcount-0 cached
    blocks, so retention never reduces admissible capacity.

    ``weight_quant`` in {'int8', 'w4a16'} serves QUANTIZED weights: params
    are rewritten to QuantWeight containers at construction, every matmul
    site (prefill chunk, decode window, mixed step, verify) dispatches the
    in-VMEM-dequant MXU kernels under a HeteroCtx or the dequantize-then-
    matmul fallback without one — the same dequantized values either way,
    so engine modes and sync arms remain token-identical. ``kv_quant='int8'``
    stores the paged pool as int8 codes with per-token-slot bf16 scales
    (quantize-on-scatter, dequant-on-gather): equal pool memory holds ~2x
    the token blocks, which is the serving-capacity lever on a
    capacity-bound SoC. Both compose with windows, mixed batching,
    speculation (draft caches stay fp), and the prefix cache (cached blocks
    retire/share/CoW as int8 codes + scales).
    """

    def __init__(self, cfg, params=None, *, num_blocks: int = 65,
                 block_size: int = 32, max_blocks_per_seq: int | None = None,
                 decode_width: int = 8, buckets=(64, 128, 256),
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 cache_dtype=None, sync: str = "host", window: int = 8,
                 engine_mode: str | None = None, eos_id: int | None = None,
                 mixed_batch: bool = False,
                 max_prefill_chunk_per_step: int | None = None,
                 spec: SpecConfig | int | None = None,
                 spec_draft_params=None, interpret: bool = True,
                 prefix_cache: bool = False,
                 weight_quant: str | None = None,
                 kv_quant: str | None = None,
                 mesh=None, tracer=None):
        if sync not in ("host", "device"):
            raise ValueError(f"sync must be 'host' or 'device', got {sync!r}")
        if mesh is not None and engine_mode is not None:
            raise ValueError(
                "engine_mode and mesh are mutually exclusive: the hetero "
                "engine partitions matmuls within one device, tensor "
                "parallelism partitions them across the mesh")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if isinstance(spec, int):
            spec = SpecConfig(k=spec)
        if spec is not None and mixed_batch:
            raise ValueError("spec mode and mixed_batch are mutually "
                             "exclusive")
        if spec is not None and sampler.temperature > 0.0:
            raise ValueError("spec mode implements greedy verification only;"
                             " use a temperature-0 sampler")
        if max_prefill_chunk_per_step is not None \
                and max_prefill_chunk_per_step < 1:
            raise ValueError("max_prefill_chunk_per_step must be >= 1, got "
                             f"{max_prefill_chunk_per_step}")
        from repro.models.quant import WEIGHT_FORMATS, quantize_params
        if weight_quant is not None and weight_quant not in WEIGHT_FORMATS:
            raise ValueError(f"weight_quant must be one of {WEIGHT_FORMATS} "
                             f"(or None), got {weight_quant!r}")
        if kv_quant not in (None, "int8"):
            raise ValueError(f"kv_quant must be 'int8' or None, "
                             f"got {kv_quant!r}")
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.paged_decode_step is None:
            raise ValueError(f"{cfg.name}: paged KV cache requires an "
                             "attention-family model")
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.weight_quant = weight_quant
        self.kv_quant = kv_quant
        if weight_quant is not None:
            # fp params in, QuantWeight-carrying params out: every matmul
            # site downstream (prefill chunks, decode windows, mixed steps,
            # verify) sees the quantized weights — dequantized identically
            # whether the HeteroCtx MXU kernels or the plan-free fallback
            # runs them, so engine modes stay token-identical
            self.params = quantize_params(self.params, cfg, weight_quant)
        # the fp activation dtype: pool storage when KV is unquantized, and
        # always the draft-lane cache dtype (draft caches stay fp)
        fp_dtype = (cache_dtype if cache_dtype is not None
                    else jnp.dtype(cfg.compute_dtype))
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        # the layout object owns physical placement (single-device identity
        # or head-sharded tensor parallelism over mesh's 'model' axis); all
        # scheduler bookkeeping below it stays replicated/device-agnostic
        from repro.serving.layout import make_layout
        self.mesh = mesh
        self.layout = make_layout(cfg, mesh)
        # observability: NULL_TRACER (shared no-op) unless the caller wires
        # a live Tracer — the pool and draft lanes record into the same one
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.kv = PagedKVCache(
            cfg, num_blocks=num_blocks, block_size=block_size,
            max_blocks_per_seq=max_blocks_per_seq,
            dtype=fp_dtype, prefix_cache=prefix_cache, kv_quant=kv_quant,
            layout=self.layout if mesh is not None else None,
            tracer=self.tracer)
        self.W = decode_width
        self.buckets = tuple(sorted(buckets))
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(seed)
        self.lanes: list[Optional[_PagedLane]] = [None] * decode_width
        self.queue: list[Request] = []
        self.peak_active = 0
        self.sync = sync
        self.window = window
        self.eos_id = eos_id
        self.engine_mode = engine_mode
        self.mixed_batch = mixed_batch
        # mixed-batch admission chunking: bucket-sized pieces no larger than
        # the per-step cap (so one step never fuses more than one cap's worth
        # of compute-bound prefill into the latency-sensitive decode path)
        cap = max_prefill_chunk_per_step
        self.max_prefill_chunk_per_step = cap
        self.admit_buckets = (self.buckets if cap is None else
                              (tuple(b for b in self.buckets if b <= cap)
                               or (cap,)))
        self._admitting: Optional[_Admission] = None
        self.spec = spec
        if engine_mode is not None:
            from repro.core.engine import build_hetero_ctx
            self.ctx = build_hetero_ctx(
                cfg, engine_mode,
                sync_mode="fast" if sync == "device" else "host",
                # offline-plan completeness, not a runtime input: fusion is
                # structural (mixed_step), but the saved plan records the
                # solver's MIXED costing of the (chunk bucket, decode width)
                # pairs this scheduler fuses, for analysis/benchmarks
                mixed_pairs=(tuple((b, decode_width)
                                   for b in self.admit_buckets)
                             if mixed_batch else ()),
                # VERIFY site class: the M = W*(K+1) verification dispatches
                # this scheduler issues in spec mode
                verify_ks=(((spec.k, decode_width),)
                           if spec is not None else ()),
                # prefix caching introduces one NEW chunk-length family:
                # suffixes start at block boundaries, so block-MULTIPLE
                # chunks below the smallest bucket become common — add
                # them to the solve grid (the M=1 full-hit logits re-run
                # is already on it). Ragged suffix tails remain arbitrary
                # lengths and use the same nearest-M fallback the cold
                # path's ragged remainders always used.
                extra_ms=(tuple(range(block_size, min(self.buckets),
                                      block_size))
                          if prefix_cache else ()),
                # quantized weight-stream bytes change the roofline: the
                # solver re-plans memory-bound (decode-width) shapes
                weight_quant=weight_quant,
                interpret=interpret)
        else:
            self.ctx = None
        # the solved plan (None without an engine mode) backs the tracer's
        # dispatch decision tags and the plan-drift report
        self._plan = self.ctx.plan if self.ctx is not None else None
        # observability: host dispatches actually issued vs tokens produced —
        # the fused-window win is decode dispatches << decode steps; the
        # mixed-batch win is prefill chunks riding decode dispatches for free
        # (fused_steps up, prefill_dispatches down, total_dispatches down)
        self.decode_dispatches = 0
        self.decode_steps = 0
        self.prefill_dispatches = 0      # standalone prefill-chunk dispatches
        self.fused_steps = 0             # prefill chunks fused into decode
        self.preemptions = 0             # lanes evicted mid-flight (ingress)
        # speculative decoding counters (spec mode): the win is
        # verify_dispatches << decode_steps; acceptance_rate explains it
        self.spec_rounds = 0             # per-lane speculation rounds
        self.drafted_tokens = 0          # K drafts offered per lane-round
        self.accepted_tokens = 0         # drafts the target verified correct
        self.verify_dispatches = 0       # batched paged_verify dispatches

        # the four paged inference paths, as the layout executes them: the
        # model's own entry points on a single device, shard_map-wrapped TP
        # variants over a mesh (stable callables — one jit cache each)
        paged_fns = self.layout.step_fns(self.model, self.params)

        if spec is not None:
            if self.model.paged_verify is None:
                raise ValueError(f"{cfg.name}: speculative decoding requires"
                                 " an attention-family target model")
            draft_cfg = spec.resolve_draft(cfg)
            self.draft_cfg = draft_cfg
            if spec_draft_params is None:
                spec_draft_params = (
                    self.params if draft_cfg is cfg else
                    build_model(draft_cfg).init(jax.random.PRNGKey(seed + 1)))
            # the longest admissible request bounds the draft cache; +k+1
            # slots absorb the round's overshooting draft writes
            self.drafts = DraftLanes(
                draft_cfg, spec_draft_params, lanes=decode_width,
                max_len=self.kv.max_blocks_per_seq * block_size + spec.k + 1,
                buckets=self.buckets, sync=sync,
                dtype=fp_dtype,       # draft caches stay fp under kv_quant
                tracer=self.tracer)
            vctx = (self.ctx.for_verify(spec.k, decode_width)
                    if self.ctx is not None else None)
            self._verify = jax.jit(partial(paged_fns["paged_verify"],
                                           hetero_ctx=vctx),
                                   donate_argnums=(2,))
            self._accept = jax.jit(greedy_verify)
        else:
            self.drafts = None

        # TP placement happens AFTER DraftLanes capture self.params: draft
        # lanes keep a deliberately-replicated (single-device) copy, so the
        # draft stream stays collective-free and bit-identical to the TP=1
        # draft; only the target model's weights shard
        self.params = self.layout.place_params(self.params)

        # the solver plan is baked in at trace time ('graphs generated in
        # advance'): jit compiles one graph per chunk length, so standard
        # buckets hit the compile cache and only a novel ragged remainder
        # pays the trace+compile that bucketing amortizes
        self._prefill = jax.jit(partial(paged_fns["paged_prefill"],
                                        hetero_ctx=self.ctx),
                                donate_argnums=(2,))
        self._decode = jax.jit(paged_fns["paged_decode_step"],
                               donate_argnums=(2,))
        # the fused-window scan body: None = the model's own step (single
        # device); the layout's shard_map step under TP (stable identity,
        # it is a static arg of the jitted window)
        self._decode_step_fn = (paged_fns["paged_decode_step"]
                                if mesh is not None else None)
        # stable callables (one jit cache each) for the mixed-batch arms:
        # decode lanes stay on the flexible path, the chunk gets the ctx
        self._mixed_step_fn = partial(paged_fns["mixed_step"],
                                      hetero_ctx=self.ctx)
        self._mixed = jax.jit(self._mixed_step_fn, donate_argnums=(3,))

    def _dispatch_span(self, kind: str, track: str, specs=(), **args):
        """Context manager for one traced dispatch: ``specs`` is a sequence
        of ``dispatch_prediction`` kwarg dicts (a fused window is mixed
        first step + plain decode rest, hence a sequence) whose decision
        tags and predicted cost annotate the span and feed the drift
        report. With the tracer disabled NOTHING here runs — no prediction
        lookup, no event — preserving the zero-overhead contract."""
        tr = self.tracer
        if not tr.enabled:
            return tr.dispatch(kind)
        from repro.core.engine import dispatch_prediction
        tags, total = [], 0.0
        for sp in specs:
            t, p = dispatch_prediction(self._plan, self.cfg, **sp)
            tags.extend(t)
            total += p
        return tr.dispatch(kind, track=track, tags=tuple(tags),
                           predicted_us=total, args=args)

    @property
    def total_dispatches(self) -> int:
        """Host dispatches issued end-to-end (prefill + decode; a fused
        mixed step counts once — that's the point). In spec mode this is
        TARGET-model dispatches; the draft model's are tracked separately
        (``stats()['draft_dispatches']``)."""
        return self.decode_dispatches + self.prefill_dispatches

    def stats(self) -> dict:
        """Unified counter snapshot: every ad-hoc dispatch/fusion/
        speculation counter behind one dict — what ``serve.py --stats``
        prints and the benches assert on. Spec-mode keys appear only when
        speculation is on (``target_dispatches`` == ``total_dispatches``:
        draft-model work is deliberately kept out of the headline
        number)."""
        s = {
            "tp": self.layout.tp,
            "peak_active": self.peak_active,
            "decode_dispatches": self.decode_dispatches,
            "decode_steps": self.decode_steps,
            "prefill_dispatches": self.prefill_dispatches,
            "fused_steps": self.fused_steps,
            "preemptions": self.preemptions,
            "total_dispatches": self.total_dispatches,
        }
        s.update(self.kv.prefix_stats())
        if self.spec is not None:
            s.update({
                "spec_k": self.spec.k,
                "draft_model": self.draft_cfg.name,
                "spec_rounds": self.spec_rounds,
                "drafted_tokens": self.drafted_tokens,
                "accepted_tokens": self.accepted_tokens,
                "acceptance_rate": (self.accepted_tokens /
                                    max(self.drafted_tokens, 1)),
                "verify_dispatches": self.verify_dispatches,
                "draft_dispatches": self.drafts.dispatches,
                "target_dispatches": self.total_dispatches,
            })
        return s

    @property
    def busy(self) -> bool:
        """Work outstanding: queued requests, an open admission ticket, or
        occupied decode lanes. External tick-drivers (benchmarks, tests)
        loop on this instead of reaching into scheduler state."""
        return bool(self.queue or self._admitting is not None
                    or any(lane is not None for lane in self.lanes))

    # ------------------------------------------------------------ plumbing --
    def submit(self, req: Request):
        live = {r.rid for r in self.queue}
        live.update(lane.req.rid for lane in self.lanes if lane is not None)
        if self._admitting is not None:
            live.add(self._admitting.req.rid)
        _validate_submit(req, live)
        self.queue.append(req)

    def _try_open(self, req: Request) -> Optional[SequenceBlocks]:
        """Admission gate shared by both admission paths: validate the
        request fits the pool at all, then reserve its blocks (or return
        None to wait FCFS for blocks to free)."""
        S = len(req.prompt)
        total = S + req.max_new_tokens   # generation headroom, see step()
        need = self.kv.blocks_for(total)
        if need > min(self.kv.max_blocks_per_seq, self.kv.num_blocks - 1):
            raise ValueError(
                f"request {req.rid} needs {need} blocks "
                f"({total} tokens @ block_size={self.block_size}) but the "
                f"pool can never supply more than "
                f"{min(self.kv.max_blocks_per_seq, self.kv.num_blocks - 1)}"
                " per request — raise num_blocks/max_blocks_per_seq")
        if not self.kv.can_admit(total):
            return None
        return self.kv.open_sequence(
            prompt_tokens=S, total_tokens=total,
            token_ids=req.prompt if self.prefix_cache else None)

    def _place(self, req: Request, seq: SequenceBlocks, first: int) -> int:
        """Prefill done: record the prefill-sampled token and occupy a lane
        (returned so spec mode can target the lane's draft cache)."""
        seq.length = len(req.prompt)
        req.output.append(first)
        budget = req.max_new_tokens - 1
        if self.eos_id is not None and first == self.eos_id:
            budget = 0                  # satisfied at prefill, like max=1
        lane = next(i for i in range(self.W) if self.lanes[i] is None)
        self.lanes[lane] = _PagedLane(req=req, seq=seq, budget=budget)
        return lane

    def _admit(self):
        """Admit-then-decode (the baseline arm): whole prompts prefill as
        their own chunk dispatches before the request joins a lane. With
        the prefix cache on, ``seq.cached_tokens`` positions are already
        resident (shared blocks) and prefill covers only the uncached
        suffix — chunking starts at the cached boundary."""
        for lane in range(self.W):
            if self.lanes[lane] is not None or not self.queue:
                continue
            seq = self._try_open(self.queue[0])
            if seq is None:
                break                    # FCFS: wait for blocks to free
            req = self.queue.pop(0)
            bt = jnp.asarray(seq.table)[None]
            idx, logits = seq.cached_tokens, None
            for c in bucket_chunks(len(req.prompt) - seq.cached_tokens,
                                   self.buckets):
                piece = jnp.asarray(req.prompt[idx: idx + c], jnp.int32)
                with self._dispatch_span("prefill_chunk", "prefill",
                                         ({"m": c},), rid=req.rid,
                                         chunk=c, start=idx):
                    logits, self.kv.pool = self._prefill(
                        self.params, piece[None], self.kv.pool,
                        block_table=bt,
                        start_index=jnp.asarray(idx, jnp.int32))
                self.prefill_dispatches += 1
                self.tracer.count("prefill_dispatches")
                idx += c
            self.rng, k = jax.random.split(self.rng)
            lane = self._place(req, seq, int(sample(logits[:, -1, :], k,
                                                    self.sampler)[0]))
            if self.spec is not None and self.lanes[lane] is not None \
                    and self.lanes[lane].budget > 0:
                # the draft model consumes the prompt too (its lane cache
                # must mirror the target's token stream before drafting)
                self.drafts.prefill(lane, req.prompt)

    def _start_admission(self):
        """Mixed batching: take ONE admission ticket at a time. A free lane
        is required up front (lanes only free while the ticket is open, so
        it stays available for `_place` at the end of the prefill)."""
        if self._admitting is not None or not self.queue:
            return
        if all(lane is not None for lane in self.lanes):
            return
        seq = self._try_open(self.queue[0])
        if seq is None:
            return
        req = self.queue.pop(0)
        self._admitting = _Admission(
            req=req, seq=seq, idx=seq.cached_tokens,
            chunks=bucket_chunks(len(req.prompt) - seq.cached_tokens,
                                 self.admit_buckets))

    def _admission_chunk(self):
        """Pop the admitting request's next chunk as device operands:
        (tokens [1, C], block table [1, NBmax], start index)."""
        adm = self._admitting
        c = adm.chunks.pop(0)
        piece = jnp.asarray(adm.req.prompt[adm.idx: adm.idx + c],
                            jnp.int32)[None]
        start = adm.idx
        adm.idx += c
        return piece, jnp.asarray(adm.seq.table)[None], start

    def _finish_admission(self, pre_logits):
        """Last chunk landed: sample the prefill token and occupy the lane
        reserved at `_start_admission`."""
        adm, self._admitting = self._admitting, None
        self.rng, k = jax.random.split(self.rng)
        self._place(adm.req, adm.seq,
                    int(sample(pre_logits[:, -1, :], k, self.sampler)[0]))

    def _close_lane(self, lane: int) -> _PagedLane:
        """Return lane ``lane``'s pool references (shared by finish and
        preemption): with the prefix cache on, full blocks of the WRITTEN
        token stream retire under their chain hash — KV position p holds
        the p-th token of prompt + output in every serving mode, and the
        last sampled token's KV is never written, so slice to
        ``seq.length``."""
        st = self.lanes[lane]
        ids = None
        if self.prefix_cache:
            ids = np.concatenate([
                np.asarray(st.req.prompt, np.int64),
                np.asarray(st.req.output, np.int64)])[:st.seq.length]
        self.kv.close_sequence(st.seq, token_ids=ids)
        self.lanes[lane] = None
        return st

    def _finish(self, lane: int):
        self._close_lane(lane).req.done = True

    def preempt(self, lane: int) -> Request:
        """Evict lane ``lane`` mid-flight, freeing its pool blocks for
        higher-priority work, and return its (unfinished) request. With the
        prefix cache on the evicted KV RETIRES instead of freeing, so a
        resume that re-submits ``prompt + output`` with the remaining
        budget hash-matches the retired blocks and re-prefills only the
        uncached suffix (recompute-on-resume through PR 5's cache). Under
        greedy decoding the resumed continuation is bit-identical to the
        un-preempted stream: the resume prompt IS the stream so far, and
        prefill logits at a position equal decode logits at that position.
        The scheduler caller (serving/ingress.py) owns the re-queueing."""
        st = self.lanes[lane]
        if st is None:
            raise ValueError(f"preempt of idle lane {lane}")
        if st.budget <= 0:
            raise ValueError(f"preempt of finishing lane {lane}: it frees "
                             "itself on the next step")
        self.preemptions += 1
        self.tracer.count("preemptions")
        self.tracer.instant("lane_preempt", track="scheduler",
                            args={"lane": lane, "rid": st.req.rid})
        if self.drafts is not None:
            self.drafts.rollback(lane, 0)   # stale draft cache: cursor home
        return self._close_lane(lane).req

    # ----------------------------------------------------------------- run --
    def step(self):
        """One tick: admit by free blocks, one batched paged decode — a
        single host-synced step (sync='host') or a fused window of
        ``self.window`` steps in one dispatch (sync='device'). Under mixed
        batching the admitting request's next prompt chunk rides the same
        dispatch; a standalone prefill dispatch happens only when no lane
        is decoding."""
        if self.mixed_batch:
            self._start_admission()
        else:
            self._admit()
        active = [i for i in range(self.W) if self.lanes[i] is not None]
        self.peak_active = max(
            self.peak_active,
            len(active) + (self._admitting is not None))
        self.tracer.gauge("peak_active", self.peak_active)
        # zero-budget admissions (max_new_tokens == 1, or EOS sampled at
        # prefill) finish without a decode step
        for i in list(active):
            if self.lanes[i].budget <= 0:
                self._finish(i)
                active.remove(i)

        if self.spec is not None:
            if not active:
                return False
            self._spec_round(active)
            return True

        adm_chunk = pre_logits = None
        if self._admitting is not None:
            adm_chunk = self._admission_chunk()
            last_chunk = not self._admitting.chunks
            if not active:
                # nothing decoding: the chunk pays its own dispatch
                piece, bt, start = adm_chunk
                c = int(piece.shape[1])
                with self._dispatch_span("prefill_chunk", "prefill",
                                         ({"m": c},),
                                         rid=self._admitting.req.rid,
                                         chunk=c, start=start):
                    pre_logits, self.kv.pool = self._prefill(
                        self.params, piece, self.kv.pool, block_table=bt,
                        start_index=jnp.asarray(start, jnp.int32))
                self.prefill_dispatches += 1
                self.tracer.count("prefill_dispatches")
            elif self.sync == "device":
                pre_logits = self._decode_window(active, adm_chunk)
            else:
                pre_logits = self._decode_tick(active, adm_chunk)
            if last_chunk:
                self._finish_admission(pre_logits)
            return True

        if not active:
            return False
        if self.sync == "device":
            self._decode_window(active)
        else:
            self._decode_tick(active)
        return True

    def _spec_round(self, active):
        """One speculative round across all active lanes: K drafts per lane
        from the per-lane draft caches (K+1 flexible-path steps — one fused
        on-device scan under ``sync='device'``), ONE batched ``paged_verify``
        target dispatch over every lane's pending+draft tokens (M = W*(K+1),
        the solver's VERIFY site class), greedy acceptance on the host, then
        token-level rollback: ``truncate_to`` returns whole pool blocks past
        each lane's accepted prefix and the draft caches reset their
        cursors. Emits 1..K+1 verified tokens per lane per target dispatch;
        the stream is bit-identical to the non-spec greedy arms."""
        k = self.spec.k
        tables = np.zeros((self.W, self.kv.max_blocks_per_seq), np.int32)
        starts = np.zeros((self.W,), np.int32)
        last = np.zeros((self.W, 1), np.int32)
        for i in active:
            st = self.lanes[i]
            # coverage capped by the remaining budget: only rows the
            # acceptance rule can emit are ever read, so growth stays
            # inside the admission reservation; writes past the covered
            # blocks sink into the null block like any masked lane
            self.kv.grow_to(st.seq, st.seq.length + min(k + 1, st.budget))
            tables[i] = st.seq.table
            starts[i] = st.seq.length
            last[i, 0] = st.req.output[-1]
        drafts = self.drafts.draft(last, k)                    # [W, k]
        tokens = np.concatenate([last, drafts], axis=1)        # [W, k+1]
        with self._dispatch_span("paged_verify", "verify",
                                 ({"verify": (k, self.W)},),
                                 k=k, lanes=len(active)):
            logits, self.kv.pool = self._verify(
                self.params, jnp.asarray(tokens), self.kv.pool,
                block_table=jnp.asarray(tables),
                start_index=jnp.asarray(starts))
        self.verify_dispatches += 1
        self.decode_dispatches += 1      # the round's one TARGET dispatch
        self.tracer.count("verify_dispatches")
        self.tracer.count("decode_dispatches")
        emitted, n_emit = self._accept(jnp.asarray(drafts), logits)
        emitted, n_emit = np.asarray(emitted), np.asarray(n_emit)
        for i in active:
            st = self.lanes[i]
            e = min(int(n_emit[i]), st.budget)
            toks = [int(t) for t in emitted[i, :e]]
            hit_eos = self.eos_id is not None and self.eos_id in toks
            if hit_eos:
                toks = toks[: toks.index(self.eos_id) + 1]
            self.spec_rounds += 1
            self.tracer.count("spec_rounds")
            # acceptance rate counts only drafts whose verification row was
            # budget-covered (rows past the coverage score null-block
            # garbage) and only acceptances that actually emitted — neither
            # side of the ratio may include schedule-truncated drafts
            self.drafted_tokens += min(k, st.budget)
            self.accepted_tokens += min(int(n_emit[i]) - 1, len(toks))
            self.tracer.count("drafted_tokens", min(k, st.budget))
            self.tracer.count("accepted_tokens",
                              min(int(n_emit[i]) - 1, len(toks)))
            st.req.output.extend(toks)
            st.budget -= len(toks)
            self.decode_steps += len(toks)
            self.tracer.count("decode_steps", len(toks))
            new_len = st.seq.length + len(toks)
            self.kv.truncate_to(st.seq, new_len)    # paged rollback
            st.seq.length = new_len
            self.drafts.rollback(i, new_len)        # draft-cache rollback
            if st.budget <= 0 or hit_eos:
                self._finish(i)

    def _decode_tick(self, active, adm_chunk=None):
        """Host-synced baseline arm: ONE decode step, one dispatch + host
        round-trip per generated token (the paper's GPU-2/clFinish cost).
        With ``adm_chunk`` the dispatch is the fused ``mixed_step`` —
        decode step ⊕ prefill chunk — and the chunk's last-token logits
        are returned."""
        tables = np.zeros((self.W, self.kv.max_blocks_per_seq), np.int32)
        lengths = np.zeros((self.W,), np.int32)
        last = np.zeros((self.W, 1), np.int32)
        for i in active:
            st = self.lanes[i]
            self.kv.maybe_grow(st.seq)   # next write may cross a boundary
            tables[i] = st.seq.table
            lengths[i] = st.seq.length
            last[i, 0] = st.req.output[-1]
        pre_logits = None
        if adm_chunk is None:
            with self._dispatch_span("decode_step", "decode",
                                     ({"m": self.W},), active=len(active)):
                logits, self.kv.pool = self._decode(
                    self.params, jnp.asarray(last), self.kv.pool,
                    block_tables=jnp.asarray(tables),
                    lengths=jnp.asarray(lengths))
        else:
            piece, bt, start = adm_chunk
            c = int(piece.shape[1])
            with self._dispatch_span("mixed_step", "decode",
                                     ({"mixed": (c, self.W)},),
                                     active=len(active), chunk=c):
                logits, pre_logits, self.kv.pool = self._mixed(
                    self.params, jnp.asarray(last), piece, self.kv.pool,
                    decode_tables=jnp.asarray(tables),
                    decode_lengths=jnp.asarray(lengths),
                    prefill_table=bt,
                    prefill_start=jnp.asarray(start, jnp.int32))
            self.fused_steps += 1
            self.tracer.count("fused_steps")
        self.decode_dispatches += 1
        self.tracer.count("decode_dispatches")
        self.rng, k = jax.random.split(self.rng)
        toks = np.asarray(sample(logits[:, -1, :], k, self.sampler))
        for i in active:
            st = self.lanes[i]
            tok = int(toks[i])
            st.req.output.append(tok)
            st.seq.length += 1
            st.budget -= 1
            self.decode_steps += 1
            self.tracer.count("decode_steps")
            if st.budget <= 0 or (self.eos_id is not None
                                  and tok == self.eos_id):
                self._finish(i)
        return pre_logits

    def _decode_window(self, active, adm_chunk=None):
        """Fast-sync arm (§4.3 at serving widths): ONE dispatch runs up to
        ``self.window`` decode steps for every lane. Each lane's block
        table is pre-grown to cover its whole window (bounded by its
        remaining budget, so growth stays inside the admission-time
        reservation); the device masks lanes that exhaust their budget or
        hit EOS mid-window; the host then reconciles outputs, lengths and
        blocks from the returned valid mask. With ``adm_chunk`` the window
        additionally carries the prefill chunk (fused into its first step)
        and returns the chunk's last-token logits."""
        from repro.core.sync import paged_decode_window

        w = self.window
        # core never imports serving: hand the window a live tracer only —
        # the disabled path passes None and core skips span construction
        win_tracer = self.tracer if self.tracer.enabled else None
        tables = np.zeros((self.W, self.kv.max_blocks_per_seq), np.int32)
        lengths = np.zeros((self.W,), np.int32)
        remaining = np.zeros((self.W,), np.int32)
        last = np.zeros((self.W, 1), np.int32)
        for i in active:
            st = self.lanes[i]
            steps = min(w, st.budget)
            # window writes positions length .. length+steps-1, all inside
            # the admission reservation (length+steps <= prompt+max_new)
            self.kv.grow_to(st.seq, st.seq.length + steps)
            tables[i] = st.seq.table
            lengths[i] = st.seq.length
            remaining[i] = steps
            last[i, 0] = st.req.output[-1]
        self.rng, sub = jax.random.split(self.rng)
        pre_logits = None
        if adm_chunk is None:
            # the compiled window always runs w full-width steps (finished
            # lanes are masked, not skipped) — predict what executes
            with self._dispatch_span("decode_window", "decode",
                                     ({"m": self.W, "steps": w},),
                                     window=w, active=len(active)):
                toks, valid, self.kv.pool, _, _ = paged_decode_window(
                    self.model, self.params, jnp.asarray(last), self.kv.pool,
                    jnp.asarray(tables), jnp.asarray(lengths),
                    jnp.asarray(remaining), sub, w,
                    sampler=self.sampler, eos_id=self.eos_id,
                    decode_step_fn=self._decode_step_fn,
                    tracer=win_tracer)
        else:
            piece, bt, start = adm_chunk
            c = int(piece.shape[1])
            # first scan step fuses the chunk (MIXED decision), the w-1
            # remaining steps are plain full-width decode
            specs = [{"mixed": (c, self.W)}]
            if w > 1:
                specs.append({"m": self.W, "steps": w - 1})
            with self._dispatch_span("mixed_window", "decode", specs,
                                     window=w, active=len(active), chunk=c):
                toks, valid, pre_logits, self.kv.pool, _, _ = \
                    paged_decode_window(
                        self.model, self.params, jnp.asarray(last),
                        self.kv.pool,
                        jnp.asarray(tables), jnp.asarray(lengths),
                        jnp.asarray(remaining), sub, w,
                        sampler=self.sampler, eos_id=self.eos_id,
                        prefill_tokens=piece, prefill_table=bt,
                        prefill_start=start,
                        mixed_step_fn=self._mixed_step_fn,
                        decode_step_fn=self._decode_step_fn,
                        tracer=win_tracer)
            self.fused_steps += 1
            self.tracer.count("fused_steps")
        self.decode_dispatches += 1
        self.tracer.count("decode_dispatches")
        toks = np.asarray(toks)
        valid = np.asarray(valid)
        for i in active:
            st = self.lanes[i]
            emitted = [int(t) for t in toks[i][valid[i]]]
            st.req.output.extend(emitted)
            st.seq.length += len(emitted)
            st.budget -= len(emitted)
            self.decode_steps += len(emitted)
            self.tracer.count("decode_steps", len(emitted))
            hit_eos = (self.eos_id is not None
                       and self.eos_id in emitted)
            if st.budget <= 0 or hit_eos:
                self._finish(i)
        return pre_logits

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        for r in requests:
            self.submit(r)
        ticks = 0
        while self.busy and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
