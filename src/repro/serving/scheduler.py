"""Continuous-batching serving scheduler.

Slot-based continuous batching over a shared KV cache: requests join free
slots, prefill runs per-request as bucket-chunked pieces (the engine's
activation-centric strategy applied at the scheduler level — aligned chunks
take the static fast path, the ragged tail takes the flexible path), decode
steps run batched across all active slots with PER-SLOT cache indices.
Finished slots free immediately and the queue backfills (orca-style
iteration-level scheduling, sized for mobile-to-pod deployments).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model

from .sampler import SamplerConfig, sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    output: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 max_len: int = 512, buckets=(64, 128, 256),
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
        assert cfg.moe is None or True
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.B, self.S = max_batch, max_len
        self.buckets = tuple(sorted(buckets))
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(seed)

        self.cache = self.model.init_cache(batch=max_batch, max_len=max_len)
        self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.queue: list[Request] = []
        self.budget: list[int] = [0] * max_batch
        self.lengths: list[int] = [0] * max_batch   # python-side slot lengths

        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self._prefill_piece = jax.jit(self._prefill_piece_impl,
                                      static_argnames=("chunk",),
                                      donate_argnums=(1,))

    # ------------------------------------------------------------ plumbing --
    def _prefill_piece_impl(self, params, cache, tokens, slot, start, *,
                            chunk: int):
        """Prefill one chunk of one request into its slot of the big cache."""
        sub = {"k": jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1),
               "v": jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1),
               "index": start}
        from repro.models import transformer
        logits, new = transformer.prefill(params, tokens[None, :], sub,
                                          self.cfg, start_index=start)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], new["k"], slot, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], new["v"], slot, axis=1)
        return logits, cache

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[b] = req
                S = len(req.prompt)
                # bucket-chunked prefill (aligned chunks + ragged tail)
                chunks, rem, idx = [], S, 0
                for bk in sorted(self.buckets, reverse=True):
                    while rem >= bk:
                        chunks.append(bk)
                        rem -= bk
                if rem:
                    chunks.append(rem)
                logits = None
                for c in chunks:
                    piece = jnp.asarray(req.prompt[idx: idx + c], jnp.int32)
                    logits, self.cache = self._prefill_piece(
                        self.params, self.cache, piece,
                        jnp.asarray(b), jnp.asarray(idx, jnp.int32), chunk=c)
                    idx += c
                self.cache["index"] = self.cache["index"].at[b].set(S)
                self.lengths[b] = S
                self.rng, k = jax.random.split(self.rng)
                first = int(sample(logits[:, -1, :], k, self.sampler)[0])
                req.output.append(first)
                self.budget[b] = req.max_new_tokens - 1

    # ----------------------------------------------------------------- run --
    def step(self):
        """One scheduler tick: admit waiting requests, one batched decode."""
        self._admit()
        active = [b for b in range(self.B) if self.slots[b] is not None]
        if not active:
            return False
        last = np.zeros((self.B, 1), np.int32)
        for b in active:
            last[b, 0] = self.slots[b].output[-1]
        # decode_step itself advances every slot's index by one
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(last), self.cache)
        self.rng, k = jax.random.split(self.rng)
        toks = np.asarray(sample(logits[:, -1, :], k, self.sampler))
        for b in active:
            req = self.slots[b]
            req.output.append(int(toks[b]))
            self.budget[b] -= 1
            self.lengths[b] += 1
            if self.budget[b] <= 0 or self.lengths[b] + 1 >= self.S:
                req.done = True
                self.slots[b] = None           # free slot; queue backfills
                self.lengths[b] = 0
        return True

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
