"""Open-loop async serving ingress: arrival generators, priority admission
with backpressure, preemption, and per-request token streaming.

PRs 1-5 built a feature-rich batcher, but everything upstream of it was a
closed loop: a fixed request list stepped to completion, measuring dispatch
counts. Real serving is OPEN-loop — requests arrive on their own schedule
whether or not the server is ready — and the paper's end-to-end numbers are
user-visible latencies under that regime. This module is the request-
lifecycle layer in front of the schedulers:

  * **Arrival generators** — seeded Poisson (:func:`poisson_arrivals`) and
    bursty on-off (:func:`burst_arrivals`) processes produce deterministic
    arrival timestamps; the same seed replays the same trace.
  * **Ingress queue** — :meth:`AsyncServer.submit` records the arrival with
    :class:`~repro.serving.telemetry.Telemetry` and parks the request in a
    priority queue (higher ``priority`` wins; FIFO within a class).
  * **Admission + backpressure** — each scheduler tick admits the
    highest-priority runnable requests into the batcher, DEFERRING
    admission whenever it would leave fewer than ``admit_watermark``
    free-plus-cached blocks in the paged pool (headroom for the decode-time
    growth of lanes already in flight).
  * **Preemption** — when a higher-priority request is blocked, the lowest-
    priority (then youngest) running lane is evicted:
    ``PagedBatcher.preempt`` closes its sequence through the prefix cache
    (full KV blocks RETIRE instead of freeing), and the request re-enters
    the queue as ``prompt + tokens-so-far`` with its remaining budget. On
    re-admission the retired blocks hash-match, so the resume re-prefills
    only the uncached suffix — recompute-on-resume is nearly free
    (PR 5's cache as the preemption store).
  * **Streaming** — ``submit`` returns a :class:`RequestHandle`, an async
    iterator yielding output tokens as the batcher produces them, with
    exactly one terminal event; ``handle.tokens`` accumulates the stream
    (preemption-transparent: a resumed request continues its stream, no
    token is ever re-emitted).

Determinism contract (the *test* archetype's real deliverable): the server
never reads wall-clock time itself — every stamp comes from the injected
:class:`Clock`. Under :class:`FakeClock` the loop only advances virtual
time (arrival sleeps collapse to ``advance``; an optional ``step_time_s``
charges a fixed virtual cost per scheduler tick), so tier-1 runs with zero
real sleeps and bitwise-reproducible telemetry. Under
:class:`MonotonicClock` the same loop serves in real time.
"""
from __future__ import annotations

import asyncio
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from .scheduler import ContinuousBatcher, PagedBatcher, Request
from .telemetry import Clock, MonotonicClock, Telemetry
from .trace import NULL_TRACER

__all__ = [
    "AsyncServer", "RequestHandle", "poisson_arrivals", "burst_arrivals",
    "arrival_times",
]


# ------------------------------------------------------------- arrivals ----

def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """Absolute arrival times of a Poisson process: ``n`` exponential
    inter-arrival gaps at ``rate`` requests/second, from a seeded
    generator — the memoryless baseline load shape."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def burst_arrivals(rate: float, n: int, seed: int = 0, *,
                   burst_size: int = 4, duty: float = 0.2) -> np.ndarray:
    """Bursty on-off arrivals at the same LONG-RUN rate as the Poisson
    process: requests land in bursts of ~``burst_size`` at ``rate/duty``
    (the on phase), separated by off gaps sized so the overall mean stays
    ``rate``. Tail latency under this shape is the backpressure test the
    smooth process never applies."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0, 1), got {duty}")
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    while len(out) < n:
        for _ in range(min(burst_size, n - len(out))):
            t += float(rng.exponential(duty / rate))       # on: dense
            out.append(t)
        t += float(rng.exponential(burst_size * (1.0 - duty) / rate))
    return np.asarray(out[:n])


def arrival_times(kind: str, rate: float, n: int, seed: int = 0) -> np.ndarray:
    """Name-dispatched generator (the ``--arrival`` CLI contract)."""
    if kind == "poisson":
        return poisson_arrivals(rate, n, seed)
    if kind == "burst":
        return burst_arrivals(rate, n, seed)
    raise ValueError(f"unknown arrival process {kind!r} "
                     "(expected 'poisson' or 'burst')")


# -------------------------------------------------------------- streaming --

class RequestHandle:
    """One request's streaming endpoint: an async iterator of output token
    ids, terminated by exactly one finish event. ``tokens`` accumulates
    everything emitted so far (survives preemption: the resumed request
    appends, never replays)."""

    def __init__(self, rid: int, priority: int = 0):
        self.rid = rid
        self.priority = priority
        self.tokens: list[int] = []
        self.done = False
        self.terminal_events = 0         # the exactly-once contract, pinned
        self._queue: asyncio.Queue = asyncio.Queue()

    def _put_token(self, tok: int) -> None:
        if self.done:
            raise RuntimeError(f"request {self.rid}: token after finish")
        self.tokens.append(tok)
        self._queue.put_nowait(tok)

    def _finish(self) -> None:
        if self.done:
            raise RuntimeError(f"request {self.rid}: finished twice")
        self.done = True
        self.terminal_events += 1
        self._queue.put_nowait(None)     # terminal sentinel

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        if self.done and self._queue.empty():
            raise StopAsyncIteration
        item = await self._queue.get()
        if item is None:
            raise StopAsyncIteration
        return item


# ------------------------------------------------------------- the server --

@dataclass
class _Entry:
    """Ingress-side request state across admissions (preemption survives)."""
    rid: int
    prompt: np.ndarray                  # the ORIGINAL prompt
    max_new_tokens: int
    priority: int
    seq_no: int                         # FIFO tiebreak within a priority
    handle: RequestHandle
    state: str = "queued"               # queued | running | done
    cur_req: Optional[Request] = None   # the batcher-side request object
    streamed: int = 0                   # cur_req.output tokens streamed
    emitted: list = field(default_factory=list)   # across all attempts


class AsyncServer:
    """Asyncio request-lifecycle layer over a batcher (paged or dense).

    The server owns the ingress queue and drives the batcher's tick loop;
    the batcher stays a synchronous, deterministic core (its own tests and
    arms are untouched). One tick = admission phase (priority order,
    watermark-gated, possibly preempting) -> one ``batcher.step()`` ->
    stream-drain phase (new tokens to handles + telemetry stamps).

    ``admit_watermark`` (paged only): admission is deferred while it would
    leave fewer than this many free+cached blocks — the backpressure that
    keeps decode-time growth of running lanes from hitting OutOfBlocks
    under open-loop load. ``preempt=True`` additionally lets a blocked
    higher-priority request evict the lowest-priority running lane.

    ``step_time_s`` charges a fixed VIRTUAL duration per tick on an
    advanceable clock (FakeClock) — deterministic stand-in for device time,
    so latency percentiles are meaningful and bitwise-reproducible in
    tests; it is rejected on a wall clock, where real time passes by
    itself.
    """

    def __init__(self, batcher, *, clock: Clock | None = None,
                 telemetry: Telemetry | None = None,
                 admit_watermark: int = 0, preempt: bool = True,
                 step_time_s: float | None = None,
                 max_ticks: int = 100_000, tracer=None):
        if not isinstance(batcher, (PagedBatcher, ContinuousBatcher)):
            raise TypeError(f"unsupported batcher {type(batcher).__name__}")
        self.batcher = batcher
        self.paged = isinstance(batcher, PagedBatcher)
        if admit_watermark and not self.paged:
            raise ValueError("admit_watermark applies to the paged batcher")
        if admit_watermark < 0:
            raise ValueError(f"admit_watermark must be >= 0, "
                             f"got {admit_watermark}")
        self.clock: Clock = clock if clock is not None else MonotonicClock()
        if step_time_s is not None and not hasattr(self.clock, "advance"):
            raise ValueError("step_time_s needs an advanceable clock "
                             "(FakeClock); a wall clock advances itself")
        self.telemetry = (telemetry if telemetry is not None
                          else Telemetry(self.clock))
        # default to the batcher's tracer so one Tracer sees the whole
        # lifecycle: ingress events land beside the dispatches they caused
        self.tracer = (tracer if tracer is not None
                       else getattr(batcher, "tracer", NULL_TRACER))
        self.admit_watermark = admit_watermark
        self.preempt_enabled = preempt and self.paged
        self.step_time_s = step_time_s
        self.max_ticks = max_ticks
        self.ticks = 0
        self.preemptions = 0             # lane evictions this server issued
        self.deferrals = 0               # watermark/capacity admission defers
        self._entries: dict[int, _Entry] = {}
        self._order: list[_Entry] = []   # submit order (stable rid listing)
        self._next_rid = 0
        self._next_seq = 0

    # ------------------------------------------------------------- intake --
    def submit(self, prompt, max_new_tokens: int = 16, *, priority: int = 0,
               rid: Optional[int] = None,
               at: Optional[float] = None) -> RequestHandle:
        """Enqueue a request, stamping its arrival (``at`` = the scheduled
        open-loop arrival time; default: now). Returns the token-stream
        handle immediately — admission happens on later ticks."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array, "
                             f"got shape {prompt.shape}")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if rid is None:
            rid = self._next_rid
        if rid in self._entries:
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        handle = RequestHandle(rid, priority)
        entry = _Entry(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                       priority=priority, seq_no=self._next_seq,
                       handle=handle)
        self._next_seq += 1
        self._entries[rid] = entry
        self._order.append(entry)
        self.telemetry.on_enqueue(rid, priority=priority, at=at)
        self.tracer.request_event("enqueue", rid, at=at,
                                  args={"priority": priority,
                                        "prompt_tokens": int(prompt.size),
                                        "max_new_tokens": max_new_tokens})
        return handle

    @property
    def handles(self) -> list[RequestHandle]:
        return [e.handle for e in self._order]

    # ---------------------------------------------------------- admission --
    def _queued(self) -> list[_Entry]:
        """Waiting entries in admission order: priority desc, then FIFO
        (a preempted request keeps its original seq_no, so it resumes ahead
        of younger work in its class)."""
        q = [e for e in self._order if e.state == "queued"]
        q.sort(key=lambda e: (-e.priority, e.seq_no))
        return q

    def _remaining(self, entry: _Entry) -> tuple[np.ndarray, int]:
        """The (prompt, budget) a (re-)admission submits: tokens already
        emitted extend the prompt — under greedy decoding the continuation
        is exactly the stream the un-preempted request would have
        produced."""
        if not entry.emitted:
            return entry.prompt, entry.max_new_tokens
        prompt = np.concatenate([
            entry.prompt, np.asarray(entry.emitted, np.int32)])
        return prompt, entry.max_new_tokens - len(entry.emitted)

    def _admit_phase(self) -> int:
        """Push runnable requests into the batcher, highest priority first,
        debiting a virtual free-block/lane budget so one tick never
        over-admits. Strict priority: a blocked request blocks its
        inferiors (and may preempt one of them)."""
        b = self.batcher
        if self.paged:
            free_lanes = sum(lane is None for lane in b.lanes)
            if b.mixed_batch:
                # one admission ticket at a time; its prefill spans ticks
                free_lanes = min(free_lanes,
                                 1 if (b._admitting is None
                                       and not b.queue) else 0)
            virtual_free = b.kv.n_free_unreserved
        else:
            free_lanes = sum(s is None for s in b.slots)
            virtual_free = 0
        admitted = 0
        for entry in self._queued():
            prompt, budget = self._remaining(entry)
            if self.paged:
                need = b.kv.blocks_for(len(prompt) + budget)
                ok = (free_lanes > 0 and need <= b.kv.max_blocks_per_seq
                      and virtual_free - need >= self.admit_watermark)
            else:
                need = 0
                ok = free_lanes > 0
            if not ok:
                self.deferrals += 1
                self.tracer.count("ingress_deferrals")
                if self._try_preempt(entry):
                    self.preemptions += 1
                    self.tracer.count("ingress_preemptions")
                break                    # strict priority FCFS
            req = Request(rid=entry.rid, prompt=prompt,
                          max_new_tokens=budget)
            b.submit(req)
            resumed = bool(entry.emitted)
            entry.cur_req = req
            entry.streamed = 0
            entry.state = "running"
            self.telemetry.on_admit(entry.rid)
            self.tracer.request_event("resume" if resumed else "admit",
                                      entry.rid)
            free_lanes -= 1
            virtual_free -= need
            admitted += 1
        return admitted

    def _try_preempt(self, blocked: _Entry) -> bool:
        """Evict one running lane strictly below ``blocked``'s priority:
        lowest priority first, youngest admission within it (least work
        lost is not the goal — freeing capacity for the high lane is).
        The victim's sequence closes through the prefix cache and the
        request re-enters the queue with its progress folded into the
        prompt."""
        if not self.preempt_enabled:
            return False
        b = self.batcher
        victims = []
        for i, lane in enumerate(b.lanes):
            if lane is None or lane.budget <= 0:
                continue                 # finishing lanes free themselves
            entry = self._entries.get(lane.req.rid)
            if entry is None or entry.priority >= blocked.priority:
                continue
            victims.append((entry.priority, -entry.seq_no, i, entry))
        if not victims:
            return False
        victims.sort(key=lambda v: v[:3])
        _, _, lane_idx, victim = victims[0]
        b.preempt(lane_idx)
        victim.cur_req = None
        victim.state = "queued"
        self.telemetry.on_preempt(victim.rid)
        self.tracer.request_event("preempt", victim.rid,
                                  args={"by": blocked.rid,
                                        "lane": lane_idx})
        return True

    # ------------------------------------------------------------ the loop --
    def _drain_phase(self) -> None:
        """Stream every token the last step produced (stamped at the
        post-step clock) and fire terminal events for finished requests."""
        for entry in self._order:
            if entry.state != "running":
                continue
            req = entry.cur_req
            new = req.output[entry.streamed:]
            for tok in new:
                entry.handle._put_token(int(tok))
                self.telemetry.on_token(entry.rid)
            entry.emitted.extend(int(t) for t in new)
            entry.streamed = len(req.output)
            if req.done:
                entry.state = "done"
                self.telemetry.on_finish(entry.rid)
                self.tracer.request_event(
                    "finish", entry.rid,
                    args={"tokens": len(entry.emitted)})
                entry.handle._finish()

    def _tick(self) -> bool:
        """One scheduler iteration: admit -> step -> drain. Returns True if
        anything progressed (admission or batcher work)."""
        self.ticks += 1
        self.tracer.count("ingress_ticks")
        with self.tracer.span("tick", track="ingress"):
            admitted = self._admit_phase()
            progressed = False
            if self.batcher.busy:
                progressed = bool(self.batcher.step())
                if self.step_time_s is not None and (progressed or admitted):
                    self.clock.advance(self.step_time_s)
            self._drain_phase()
        return bool(admitted) or progressed

    @property
    def _has_work(self) -> bool:
        return (self.batcher.busy
                or any(e.state != "done" for e in self._order))

    async def run(self, arrivals: Iterable[tuple[float, dict]] = (),
                  ) -> list[RequestHandle]:
        """Drive the server until every submitted request (and every
        scheduled arrival) finishes. ``arrivals`` is an iterable of
        ``(time, submit_kwargs)`` — the open-loop source: each request is
        submitted when the clock reaches its time, stamped AT that time
        (the arrival happened whether or not the server was busy). Between
        ticks the loop yields to the event loop, so ``async for`` consumers
        stream concurrently; when idle it sleeps (virtually, under
        FakeClock) until the next arrival. Returns all handles in submit
        order."""
        pending = deque(sorted(arrivals, key=lambda a: a[0]))
        stalled = 0
        while True:
            now = self.clock.now()
            while pending and pending[0][0] <= now + 1e-9:
                t, kw = pending.popleft()
                self.submit(**kw, at=t)
            if self._has_work:
                progressed = self._tick()
                if self.ticks > self.max_ticks:
                    raise RuntimeError(
                        f"ingress exceeded max_ticks={self.max_ticks}")
                if progressed or self.batcher.busy:
                    stalled = 0
                else:
                    # queued work, idle batcher, nothing admitted: only an
                    # arrival or a freed lane could unblock — with neither
                    # in sight this is a permanent stall, fail loudly
                    stalled += 1
                    if not pending and stalled > 2:
                        blocked = [e.rid for e in self._queued()]
                        raise RuntimeError(
                            f"ingress stalled: requests {blocked} can never "
                            f"admit (watermark={self.admit_watermark}, "
                            f"pool too small, or every lane above their "
                            "priority)")
                await asyncio.sleep(0)   # let stream consumers run
            elif pending:
                await self.clock.sleep(pending[0][0] - now)
            else:
                break
        return self.handles

    def run_sync(self, arrivals: Iterable[tuple[float, dict]] = (),
                 ) -> list[RequestHandle]:
        """``asyncio.run`` wrapper for non-async callers (benchmarks, the
        CLI's closed-loop path)."""
        return asyncio.run(self.run(arrivals))

    # -------------------------------------------------------------- stats --
    def stats(self) -> dict:
        """Batcher counters + ingress-level admission/preemption counters."""
        s = dict(self.batcher.stats())
        s.update({"ingress_ticks": self.ticks,
                  "ingress_preemptions": self.preemptions,
                  "ingress_deferrals": self.deferrals})
        return s

    def report(self, slo_ms: Optional[float] = None) -> dict:
        return self.telemetry.report(slo_ms=slo_ms)


def open_loop_workload(prompts, budgets, times, priorities=None
                       ) -> list[tuple[float, dict]]:
    """Zip a prompt set with arrival times into ``AsyncServer.run``'s
    arrival schedule (rid = position, so references index directly)."""
    if priorities is None:
        priorities = [0] * len(prompts)
    return [(float(t), dict(prompt=p, max_new_tokens=int(m), rid=i,
                            priority=int(pr)))
            for i, (p, m, t, pr) in enumerate(
                zip(prompts, budgets, times, priorities))]
