"""Heterogeneous speculative decoding: draft on the flexible path, verify
K+1 tokens per target dispatch on the solver-planned path, roll back the
paged KV cache to the accepted prefix.

The paper's characterization (§3, §4.2) leaves decode stranded: the
aligned/NPU-style path only pays off at 128-stage token counts, while
decode (M=1) is memory-bound flexible-path work — the same stage-level gap
measured by *When NPUs Are Not Always Faster* (arXiv:2605.27435) and the
on-device decode bottleneck in *Understanding LLMs in Your Pockets*
(arXiv:2410.03613). Speculative decoding converts decode into M=K+1
verification batches — precisely the stage-shaped workload the aligned path
accelerates, and the one decode-side workload whose M the SCHEDULER gets to
choose. Three pieces, spread across the stack:

  * **Draft** — a small model (`SpecConfig.draft`, e.g. ``smollm-135m``; or
    the target itself for self-speculation) greedily proposes K tokens per
    round on the flexible path. :class:`DraftLanes` holds the per-lane
    draft caches (one batched dense cache, per-lane write cursors), with
    the K-step draft loop either host-synced or fused into ONE on-device
    ``lax.scan`` dispatch (``sync='device'``, §4.3 applied to the draft).
  * **Verify** — ONE target-model dispatch
    (``models/transformer.py::paged_verify``) scores all K+1 positions
    (pending token + K drafts) over cached-prefix + appended tokens,
    routed through a ``HeteroCtx`` whose plan includes the solver's VERIFY
    site class (``core/solver.py::solve_verify`` — M = lanes*(K+1) lands in
    act/hybrid territory). Greedy acceptance
    (``serving/sampler.py::greedy_verify``) is lossless: emitted tokens are
    bit-identical to per-token greedy decoding of the target, whatever the
    drafts were.
  * **Rollback** — rejected positions are reclaimed token-level by
    ``PagedKVCache.truncate_to`` (whole blocks past the accepted prefix
    return to the free list, inside the admission reservation); stale pool
    slots are masked positionally and rewritten before any later query
    attends them, so rollback costs nothing on the device side.

:class:`SpecDecoder` is the single-stream engine (one request, lanes=1);
``serving/scheduler.py::PagedBatcher(spec=...)`` runs the same round
batched across decode lanes. This is the first subsystem where TWO models
coexist in one serving process.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model

from .paged_cache import PagedKVCache
from .sampler import greedy_verify


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding settings.

    ``draft``: the draft model — a config name from ``repro/configs``
    (e.g. ``"smollm-135m"``), a ``ModelConfig`` instance, or None for
    self-speculation (the target drafts for itself: the acceptance-rate
    upper bound, useful for benchmarks). ``smoke`` resolves a name via
    ``get_smoke_config`` instead of ``get_config``. ``k`` is the
    speculation length: drafts per round, so up to k+1 tokens emitted per
    target dispatch. Only greedy verification is implemented — it is the
    arm whose output stream is provably identical to non-speculative
    greedy decoding.
    """
    k: int = 4
    draft: Any = None                # name | ModelConfig | None (self-draft)
    smoke: bool = False              # name resolution: smoke-scale configs
    greedy: bool = True

    def resolve_draft(self, target_cfg):
        """Resolve ``draft`` to a ModelConfig and validate the pairing."""
        if self.k < 1:
            raise ValueError(f"speculation length k must be >= 1, got {self.k}")
        if not self.greedy:
            raise NotImplementedError(
                "only greedy verification is implemented")
        d = self.draft
        if isinstance(d, str):
            from repro.configs import get_config, get_smoke_config
            d = get_smoke_config(d) if self.smoke else get_config(d)
        elif d is None:
            d = target_cfg
        if d.vocab_size != target_cfg.vocab_size:
            raise ValueError(
                f"draft {d.name} (vocab {d.vocab_size}) and target "
                f"{target_cfg.name} (vocab {target_cfg.vocab_size}) must "
                "share one token space for speculative decoding")
        if d.encoder_only or d.rwkv is not None or d.ssm is not None:
            raise ValueError(f"draft {d.name}: drafting needs a decoder "
                             "attention-family model")
        return d


class DraftLanes:
    """Per-lane draft-model caches behind one batched dense KV cache.

    Each of ``lanes`` decode lanes owns a slot (its 'draft cache'): a
    ``[lanes, max_len]`` dense KV region plus a host-authoritative write
    cursor. Prompts prefill bucket-chunked into their slot; each draft
    round runs k+1 greedy steps — feeding the pending token, then each
    draft including the k-th, so a fully-accepted round leaves no cache
    hole — and rollback is a cursor reset (stale slots past the cursor are
    positionally masked and rewritten before any later query attends them,
    the same invariant the paged pool relies on).

    ``sync='host'`` dispatches each draft step separately;
    ``sync='device'`` fuses the whole round into one jitted ``lax.scan``
    (``core/sync.py::generate_on_device`` — fast sync applied to the
    draft). ``dispatches`` counts every draft-model dispatch (prefill
    chunks included); the spec win is measured in TARGET dispatches, but
    the draft-side cost stays observable.
    """

    def __init__(self, cfg, params, *, lanes: int, max_len: int,
                 buckets=(64, 128, 256), sync: str = "host", dtype=None,
                 tracer=None):
        from .trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.W = lanes
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets))
        self.sync = sync
        dtype = dtype if dtype is not None else jnp.dtype(cfg.compute_dtype)
        self.cache = self.model.init_cache(batch=lanes, max_len=max_len,
                                           dtype=dtype)
        self.cache["index"] = jnp.zeros((lanes,), jnp.int32)
        self.idx = np.zeros((lanes,), np.int32)   # per-lane write cursors
        self.dispatches = 0
        self._step = jax.jit(self.model.decode_step, donate_argnums=(2,))
        from repro.models import transformer
        self._prefill_piece = jax.jit(partial(transformer.prefill_slot,
                                              cfg=cfg),
                                      static_argnames=("chunk",),
                                      donate_argnums=(1,))

    def prefill(self, lane: int, prompt: np.ndarray) -> None:
        """Bucket-chunked prompt prefill into ``lane``'s slot."""
        from .scheduler import bucket_chunks   # deferred: avoids a cycle
        idx = 0
        for c in bucket_chunks(len(prompt), self.buckets):
            piece = jnp.asarray(prompt[idx: idx + c], jnp.int32)
            with self.tracer.dispatch("draft_prefill_chunk", track="draft",
                                      args={"lane": lane, "chunk": c,
                                            "start": idx}):
                _, self.cache = self._prefill_piece(
                    self.params, self.cache, piece, jnp.asarray(lane),
                    jnp.asarray(idx, jnp.int32), chunk=c)
            self.dispatches += 1
            self.tracer.count("draft_dispatches")
            idx += c
        self.idx[lane] = len(prompt)

    def draft(self, last: np.ndarray, k: int) -> np.ndarray:
        """One draft round: feed each lane's pending token (``last`` [W, 1])
        and greedily roll k+1 steps forward. Returns drafts [W, k] (the
        k+1-th prediction is discarded — that step exists to WRITE the
        k-th draft's KV so full acceptance leaves the cache gapless).
        Inactive lanes draft garbage that the caller discards."""
        cache = {**self.cache, "index": jnp.asarray(self.idx)}
        tok = jnp.asarray(last, jnp.int32)
        with self.tracer.dispatch("spec_draft", track="draft",
                                  args={"k": k, "sync": self.sync,
                                        "lanes": self.W}):
            if self.sync == "device":
                from repro.core.sync import generate_on_device
                toks, self.cache = generate_on_device(self.model, self.params,
                                                      tok, cache, k + 1)
                self.dispatches += 1
                self.tracer.count("draft_dispatches")
            else:
                outs = []
                for _ in range(k + 1):
                    logits, cache = self._step(self.params, tok, cache)
                    tok = jnp.argmax(logits[:, -1, :], axis=-1
                                     ).astype(jnp.int32)[:, None]
                    outs.append(tok[:, 0])
                    self.dispatches += 1
                    self.tracer.count("draft_dispatches")
                self.cache = cache
                toks = jnp.stack(outs, axis=1)
        self.idx = self.idx + np.int32(k + 1)
        return np.asarray(toks[:, :k])

    def rollback(self, lane: int, n_tokens: int) -> None:
        """Reset ``lane``'s cursor to the accepted token count — the whole
        draft-side rollback (stale cache beyond it is masked/rewritten)."""
        self.idx[lane] = n_tokens


class SpecDecoder:
    """Single-stream speculative decoding over the paged KV pool.

    One request at a time: prompt prefills through the (optional)
    solver-planned ``HeteroCtx``, then rounds of draft (flexible path) →
    ``paged_verify`` (one target dispatch, VERIFY-planned matmuls) →
    ``greedy_verify`` acceptance → ``truncate_to`` rollback, until the
    token budget (or ``eos_id``) is hit. Greedy outputs are identical to
    per-token greedy decoding of the target — drafting only changes how
    many target dispatches that stream costs.

    The serving-scale version of the same round is
    ``serving/scheduler.py::PagedBatcher(spec=...)``; this class is the
    paper-faithful single-stream arm the benchmarks sweep.
    """

    def __init__(self, cfg, params=None, *, spec: SpecConfig = SpecConfig(),
                 draft_params=None, num_blocks: Optional[int] = None,
                 block_size: int = 32, max_len: int = 512,
                 buckets=(64, 128, 256), engine_mode: Optional[str] = None,
                 sync: str = "host", eos_id: Optional[int] = None,
                 cache_dtype=None, seed: int = 0, interpret: bool = True):
        if sync not in ("host", "device"):
            raise ValueError(f"sync must be 'host' or 'device', got {sync!r}")
        self.cfg = cfg
        self.model = build_model(cfg)
        if self.model.paged_verify is None:
            raise ValueError(f"{cfg.name}: speculative decoding requires an "
                             "attention-family target model")
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.spec = spec
        self.eos_id = eos_id
        self.buckets = tuple(sorted(buckets))
        self.max_len = max_len
        dtype = (cache_dtype if cache_dtype is not None
                 else jnp.dtype(cfg.compute_dtype))
        num_blocks = (num_blocks if num_blocks is not None
                      else 1 + -(-(max_len + spec.k) // block_size))
        self.kv = PagedKVCache(cfg, num_blocks=num_blocks,
                               block_size=block_size, dtype=dtype)

        draft_cfg = spec.resolve_draft(cfg)
        self.draft_cfg = draft_cfg
        if draft_params is None:
            draft_params = (self.params if draft_cfg is cfg else
                            build_model(draft_cfg).init(
                                jax.random.PRNGKey(seed + 1)))
        self.drafts = DraftLanes(draft_cfg, draft_params, lanes=1,
                                 max_len=max_len + spec.k + 1,
                                 buckets=buckets, sync=sync,
                                 dtype=jnp.float32 if dtype == jnp.float32
                                 else None)

        if engine_mode is not None:
            from repro.core.engine import build_hetero_ctx
            self.ctx = build_hetero_ctx(
                cfg, engine_mode,
                sync_mode="fast" if sync == "device" else "host",
                verify_ks=((spec.k, 1),), interpret=interpret)
            vctx = self.ctx.for_verify(spec.k, 1)
        else:
            self.ctx = vctx = None
        self._prefill = jax.jit(partial(self.model.paged_prefill,
                                        hetero_ctx=self.ctx),
                                donate_argnums=(2,))
        self._verify = jax.jit(partial(self.model.paged_verify,
                                       hetero_ctx=vctx),
                               donate_argnums=(2,))
        self._accept = jax.jit(greedy_verify)
        # observability: the spec win is target dispatches vs emitted tokens
        self.rounds = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.prefill_dispatches = 0
        self.verify_dispatches = 0
        self.emitted_tokens = 0

    def stats(self) -> dict:
        """Counter snapshot, same contract as the batchers' ``stats()``."""
        return {
            "spec_k": self.spec.k,
            "draft_model": self.draft_cfg.name,
            "rounds": self.rounds,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "acceptance_rate": (self.accepted_tokens /
                                max(self.drafted_tokens, 1)),
            "prefill_dispatches": self.prefill_dispatches,
            "verify_dispatches": self.verify_dispatches,
            "draft_dispatches": self.drafts.dispatches,
            "target_dispatches": (self.prefill_dispatches +
                                  self.verify_dispatches),
            "emitted_tokens": self.emitted_tokens,
        }

    def generate(self, prompt: np.ndarray, max_new_tokens: int = 16
                 ) -> list[int]:
        """Greedy-generate ``max_new_tokens`` tokens after ``prompt``
        ([S] int32). Returns the emitted token list."""
        from .scheduler import bucket_chunks   # deferred: avoids a cycle
        S = len(prompt)
        if S + max_new_tokens + self.spec.k > self.max_len:
            raise ValueError(f"prompt {S} + budget {max_new_tokens} exceeds "
                             f"max_len {self.max_len}")
        seq = self.kv.open_sequence(prompt_tokens=S,
                                    total_tokens=S + max_new_tokens)
        bt = jnp.asarray(seq.table)[None]
        idx, logits = 0, None
        for c in bucket_chunks(S, self.buckets):
            piece = jnp.asarray(prompt[idx: idx + c], jnp.int32)
            logits, self.kv.pool = self._prefill(
                self.params, piece[None], self.kv.pool, block_table=bt,
                start_index=jnp.asarray(idx, jnp.int32))
            self.prefill_dispatches += 1
            idx += c
        seq.length = S
        self.drafts.prefill(0, np.asarray(prompt))

        k = self.spec.k
        out = [int(jnp.argmax(logits[0, -1]))]
        budget = max_new_tokens - 1
        if self.eos_id is not None and out[0] == self.eos_id:
            budget = 0
        while budget > 0:
            # coverage: only rows the acceptance rule can emit are read, so
            # growth is capped by the remaining budget (stays inside the
            # admission reservation); writes past it sink in the null block
            self.kv.grow_to(seq, seq.length + min(k + 1, budget))
            # re-snapshot the table EVERY round: grow_to/truncate_to mutate
            # the host-side seq.table, and a stale device copy would alias
            # newly-grown positions into the null block
            bt = jnp.asarray(seq.table)[None]
            last = np.asarray([[out[-1]]], np.int32)
            drafts = self.drafts.draft(last, k)                  # [1, k]
            tokens = np.concatenate([last, drafts], axis=1)      # [1, k+1]
            logits, self.kv.pool = self._verify(
                self.params, jnp.asarray(tokens), self.kv.pool,
                block_table=bt,
                start_index=jnp.asarray([seq.length], jnp.int32))
            self.verify_dispatches += 1
            emitted, n_emit = self._accept(jnp.asarray(drafts), logits)
            round_budget = budget
            e = min(int(n_emit[0]), budget)
            toks = [int(t) for t in np.asarray(emitted)[0, :e]]
            if self.eos_id is not None and self.eos_id in toks:
                toks = toks[: toks.index(self.eos_id) + 1]
                budget = len(toks)                       # exhausted below
            self.rounds += 1
            # acceptance rate counts only drafts whose verification row was
            # budget-covered (rows past the coverage score null-block
            # garbage) and only acceptances that actually emitted — neither
            # side of the ratio may include schedule-truncated drafts
            self.drafted_tokens += min(k, round_budget)
            self.accepted_tokens += min(int(n_emit[0]) - 1, len(toks))
            out.extend(toks)
            budget -= len(toks)
            new_len = seq.length + len(toks)
            self.kv.truncate_to(seq, new_len)            # paged rollback
            seq.length = new_len
            self.drafts.rollback(0, new_len)             # draft rollback
        self.emitted_tokens += len(out)
        self.kv.close_sequence(seq)
        return out
