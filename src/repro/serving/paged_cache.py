"""Paged (block-table) KV cache: vLLM-style paging for the serving stack.

The paper's §3/§4.2 observation — unified-memory mobile SoCs are bound by
memory capacity and bandwidth, not compute — makes KV memory the scaling
lever for multi-request serving. The dense continuous batcher preallocates
``[max_batch, max_len]`` per slot, so one long request reserves worst-case
memory and concurrency is capped at ``max_batch`` regardless of actual
lengths. Here the cache is a shared pool of fixed-size token blocks:

  * pool tensors ``k``/``v``: ``[L, num_blocks, block_size, Hkv, D]``;
  * a host-side refcounted free-list :class:`BlockAllocator` hands blocks
    to requests;
  * each request owns a **block table** (``[max_blocks_per_seq]`` int32 of
    pool block ids) mapping logical token position ``t`` to physical slot
    ``table[t // block_size] * block_size + t % block_size``;
  * device reads gather pages with ``jnp.take`` and writes scatter through
    flat ``.at[idx].set`` — both fully jittable, so batched decode stays a
    single compiled graph.

Block id 0 is reserved as the **null block**: unused table entries point at
it, so gathers are always in-bounds (garbage there is masked positionally by
the causal mask, exactly how the dense path masks unwritten slots) and
inactive decode lanes harmlessly sink their writes into it.

Allocator invariants (enforced — misuse raises, never corrupts):
  * block 0 is never handed out and never freed;
  * every non-null block is in exactly one of three states: FREE (on the
    free list), OWNED (refcount >= 1 — held by one or more sequences), or
    CACHED (refcount 0 but retained by the prefix cache, reclaimable);
  * ``free + owned + cached == num_blocks - 1`` at all times;
  * freeing the null block, an unowned block, or an already-free block
    raises :class:`BlockAccountingError` instead of silently corrupting
    the accounting.

Growth is two-phase (``open_sequence`` reserves, ``grow_to`` draws on the
reservation) and reversible: ``truncate_to`` rolls a sequence back to an
accepted token prefix, returning whole blocks past it to the free list while
keeping them inside the reservation — the speculative-decoding rollback
primitive (serving/spec.py).

Automatic prefix caching (``prefix_cache=True``, the dominant on-device
pattern of thousands of requests sharing one system prompt):

  * every FULL block of a finished sequence is indexed by a **content hash
    chained over its token ids**
    (``h_i = SHA256(h_{i-1} || tokens[i*bs:(i+1)*bs])`` — the chain makes
    the digest position- and prefix-dependent, so equal token windows at
    different prefixes never collide, and the cryptographic digest makes
    the key a faithful stand-in for the tokens themselves);
  * ``close_sequence`` RETIRES blocks to the cache instead of freeing them:
    a retired block whose refcount drops to 0 parks in an LRU of evictable
    cached blocks, its KV contents intact;
  * ``open_sequence`` walks the new prompt's chain hashes and SHARES every
    consecutively-matching physical block (refcount + 1, or reactivated out
    of the LRU), so prefill only has to run the uncached suffix;
  * cached blocks are **immutable** while registered: when a hit covers the
    entire prompt, the sequence still needs last-token logits, so the final
    cached block is **copied-on-write** into a private block before the
    1-token suffix re-runs — a shared block is never written by two owners;
  * allocation pressure reclaims refcount-0 cached blocks in LRU order
    (``evictions`` counts them) — ``OutOfBlocks`` is only raised once the
    free list AND the evictable cache are exhausted.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAccountingError(RuntimeError):
    """Raised on allocator misuse (double free, freeing the null block,
    touching a block in the wrong state) — loud failure instead of silently
    corrupting the ``free + owned + cached == num_blocks - 1`` invariant."""


class BlockAllocator:
    """Refcounted free-list allocator over pool blocks ``1..num_blocks-1``
    (0 = null). ``alloc`` hands out blocks at refcount 1; ``incref`` lets a
    second sequence share a block (prefix caching); ``free``/``retire``
    drop a reference — a block leaves the OWNED state only when its
    refcount hits 0, landing on the free list (``free``) or in the CACHED
    set (``retire``, prefix-cache retention). ``reactivate`` pulls a CACHED
    block back to OWNED on a cache hit; ``evict`` returns it to the free
    list under allocation pressure."""

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least one allocatable block"
        self.num_blocks = num_blocks
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}      # OWNED: block -> refcount >= 1
        self._cached: set[int] = set()      # CACHED: refcount 0, retained
        self.total_allocs = 0               # fresh blocks handed out, ever

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"requested {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        self.total_allocs += n
        return out

    def incref(self, block: int) -> None:
        """Share an OWNED block with one more sequence (prefix-cache hit on
        a block whose original owner is still live)."""
        if block not in self._ref:
            raise BlockAccountingError(f"incref of unowned block {block}")
        self._ref[block] += 1

    def _drop_ref(self, block: int) -> bool:
        """Drop one reference; True iff the refcount hit 0."""
        if block == 0:
            raise BlockAccountingError("null block must never be freed")
        if block not in self._ref:
            state = ("free" if block in self._free else
                     "cached" if block in self._cached else "unknown")
            raise BlockAccountingError(
                f"double free of block {block} (state: {state})")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            return True
        return False

    def free(self, blocks: list[int]) -> None:
        """Drop one reference per block; zero-ref blocks return to the free
        list. Raises :class:`BlockAccountingError` on the null block or a
        block not currently owned (double free)."""
        for b in blocks:
            if self._drop_ref(b):
                self._free.append(b)

    def retire(self, blocks: list[int]) -> list[int]:
        """Drop one reference per block; zero-ref blocks move to the CACHED
        set (prefix-cache retention) instead of the free list. Returns the
        blocks that became cached (still-shared blocks stay OWNED)."""
        newly_cached = []
        for b in blocks:
            if self._drop_ref(b):
                self._cached.add(b)
                newly_cached.append(b)
        return newly_cached

    def reactivate(self, block: int) -> None:
        """CACHED -> OWNED at refcount 1 (prefix-cache hit on an evictable
        block)."""
        if block not in self._cached:
            raise BlockAccountingError(f"reactivate of non-cached {block}")
        self._cached.remove(block)
        self._ref[block] = 1

    def evict(self, blocks: list[int]) -> None:
        """CACHED -> FREE (allocation-pressure reclaim)."""
        for b in blocks:
            if b not in self._cached:
                raise BlockAccountingError(f"evict of non-cached block {b}")
            self._cached.remove(b)
            self._free.append(b)

    def check(self) -> None:
        assert (len(self._free) + len(self._ref) + len(self._cached)
                == self.num_blocks - 1)
        assert 0 not in self._ref and 0 not in self._free
        assert 0 not in self._cached
        assert not self._cached & set(self._free)
        assert not (self._cached | set(self._free)) & set(self._ref)
        assert all(r >= 1 for r in self._ref.values())


@dataclass
class SequenceBlocks:
    """One request's view of the pool: its block table and write cursor."""
    table: np.ndarray                  # [max_blocks_per_seq] int32, 0-padded
    blocks: list = field(default_factory=list)   # allocated pool block ids
    length: int = 0                    # tokens written so far
    reserved: int = 0                  # blocks admission promised (incl. held)
    cached_tokens: int = 0             # prefix tokens served from the cache
    n_shared: int = 0                  # leading blocks shared with the cache

    def append_block(self, block_id: int) -> None:
        self.table[len(self.blocks)] = block_id
        self.blocks.append(block_id)


@partial(jax.jit, donate_argnums=(0,))
def _cow_copy(pool: dict, src, dst) -> dict:
    """Copy one pool block's KV pages ``src`` -> ``dst`` across all layers
    (the copy-on-write primitive). src/dst are traced scalars, so every
    (src, dst) pair reuses one compiled graph."""
    out = dict(pool)
    for key in pool:        # k/v pages AND (int8 pools) their scale planes —
        # every pool tensor keeps blocks on axis 1 ([L, NB, ...]), so one
        # take/update pair copies codes and scales alike
        page = jnp.take(pool[key], src[None], axis=1)      # [L, 1, bs, H, D]
        out[key] = jax.lax.dynamic_update_slice_in_dim(
            pool[key], page, dst, axis=1)
    return out


class PagedKVCache:
    """Shared KV pool + allocator + per-request block tables.

    The device arrays live in ``self.pool`` (``{"k","v"}``, each
    ``[L, num_blocks, block_size, Hkv, D]``); scheduler code threads that
    dict through the jitted paged prefill/decode functions and stores the
    donated result back.

    With ``prefix_cache=True`` the pool additionally runs automatic prefix
    caching (module docstring): pass the prompt's token ids to
    ``open_sequence`` and the returned sequence may start with
    ``cached_tokens`` positions already resident (``seq.cached_tokens`` —
    prefill only the suffix), and pass the written token stream to
    ``close_sequence`` so full blocks retire into the hash-indexed cache
    for future requests.
    """

    def __init__(self, cfg, *, num_blocks: int, block_size: int = 32,
                 max_blocks_per_seq: int | None = None, dtype=jnp.bfloat16,
                 prefix_cache: bool = False, kv_quant: str | None = None,
                 layout=None, tracer=None):
        from repro.models import transformer
        from .trace import NULL_TRACER
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.kv_quant = kv_quant
        self.max_blocks_per_seq = (max_blocks_per_seq
                                   if max_blocks_per_seq is not None
                                   else num_blocks - 1)
        self.pool = transformer.init_paged_cache(
            cfg, num_blocks=num_blocks, block_size=block_size, dtype=dtype,
            kv_quant=kv_quant)
        # all bookkeeping below reasons about LOGICAL block ids only; the
        # layout object (serving/layout.py) is the single owner of physical
        # placement, so a head-sharded pool changes nothing here
        self.layout = layout
        if layout is not None:
            self.pool = layout.place_pool(self.pool)
        self.allocator = BlockAllocator(num_blocks)
        self._reserved_unheld = 0      # promised at admission, not yet alloc'd
        self.prefix_cache = prefix_cache
        # content-hash index over CLOSED full blocks (chained, see module
        # docstring) + LRU over the refcount-0 subset (eviction order)
        self._block_of_hash: dict = {}           # chain hash -> block id
        self._hash_of_block: dict = {}           # block id  -> chain hash
        self._lru: OrderedDict = OrderedDict()   # refcount-0 cached, LRU
        # observability (surfaced by PagedBatcher.stats())
        self.prefix_hits = 0           # admissions that reused >= 1 block
        self.prefix_tokens_reused = 0  # prompt tokens served from the cache
        self.evictions = 0             # cached blocks reclaimed for space
        self.cow_copies = 0            # copy-on-write block duplications

    # ------------------------------------------------------------- sizing --
    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 1) / self.block_size)

    @property
    def n_free_unreserved(self) -> int:
        """Blocks available to NEW admissions (free plus evictable cached,
        minus outstanding IOUs): a cached block is real capacity — pressure
        reclaims it — so retention never shrinks the admissible pool."""
        return (self.allocator.n_free + self.allocator.n_cached
                - self._reserved_unheld)

    def can_admit(self, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens)
        return (need <= self.max_blocks_per_seq
                and need <= self.n_free_unreserved)

    # ------------------------------------------------------ prefix cache --
    def _chain_hashes(self, token_ids, n_full: int) -> list:
        """Chained content digests of the first ``n_full`` full blocks of
        ``token_ids``: ``h_i = SHA256(h_{i-1} || block_i_tokens)``. Prefix-
        dependent by construction, so a hit at block i certifies the whole
        prefix [0, (i+1)*block_size). SHA-256 rather than Python ``hash``
        because a hit hands another request's KV to this one with no
        further token comparison: a 64-bit non-cryptographic hash would
        make silent cross-request KV confusion craftable (and merely
        unlucky at fleet scale), a cryptographic digest makes the index
        key a faithful stand-in for the tokens themselves."""
        bs = self.block_size
        h, out = b"%d" % self.block_size, []
        for i in range(n_full):
            block = np.asarray(token_ids[i * bs:(i + 1) * bs], np.int64)
            h = hashlib.sha256(h + block.tobytes()).digest()
            out.append(h)
        return out

    def _acquire_cached(self, block: int) -> None:
        """Take a reference on a hash-registered block: reactivate it out of
        the LRU if nobody holds it, otherwise share the live owner's copy."""
        if block in self._lru:
            del self._lru[block]
            self.allocator.reactivate(block)
        else:
            self.allocator.incref(block)

    def _release(self, blocks: list[int]) -> None:
        """Drop one reference per block, routing by registration: hash-
        registered blocks RETIRE (refcount 0 -> CACHED + LRU tail, contents
        retained for future hits), unregistered blocks free normally."""
        registered = [b for b in blocks if b in self._hash_of_block]
        plain = [b for b in blocks if b not in self._hash_of_block]
        if plain:
            self.allocator.free(plain)
        for b in self.allocator.retire(registered):
            self._lru[b] = None                  # most-recently-retired last
        self.tracer.gauge("cached_blocks", self.allocator.n_cached)

    def _reclaim(self, n: int) -> None:
        """Evict up to ``n`` refcount-0 cached blocks, least recently used
        first, unregistering their hashes. Stops early if the LRU drains
        (the subsequent ``alloc`` then raises OutOfBlocks)."""
        while n > 0 and self._lru:
            b, _ = self._lru.popitem(last=False)
            h = self._hash_of_block.pop(b)
            del self._block_of_hash[h]
            self.allocator.evict([b])
            self.evictions += 1
            self.tracer.count("evictions")
            self.tracer.instant("prefix_evict", track="cache", cat="cache",
                                args={"block": b})
            n -= 1
        self.tracer.gauge("cached_blocks", self.allocator.n_cached)

    def _alloc(self, n: int) -> list[int]:
        """Allocate ``n`` fresh blocks, evicting cached blocks on pressure."""
        if n > self.allocator.n_free:
            self._reclaim(n - self.allocator.n_free)
        return self.allocator.alloc(n)

    def _match_prefix(self, seq: SequenceBlocks, token_ids,
                      prompt_tokens: int) -> None:
        """Walk the prompt's chain hashes, sharing every consecutively-
        matching cached block into ``seq``. Sets ``seq.cached_tokens`` (the
        resident prefix prefill can skip) and ``seq.n_shared``. When the
        match covers the WHOLE prompt the last matched block is copied on
        write (a private duplicate) so the 1-token logits re-run never
        writes a shared block — ``cached_tokens`` is then ``prompt - 1``."""
        bs = self.block_size
        hits = []
        for h in self._chain_hashes(token_ids, prompt_tokens // bs):
            b = self._block_of_hash.get(h)
            if b is None:
                break
            hits.append(b)
        if not hits:
            return
        cow = len(hits) * bs == prompt_tokens
        for b in (hits[:-1] if cow else hits):
            self._acquire_cached(b)
            seq.append_block(b)
        seq.n_shared = len(seq.blocks)
        if cow:
            # full-prompt hit: last-token logits still need one forward
            # step writing position prompt-1, which lands INSIDE the last
            # cached block — duplicate it first (immutability of cached
            # blocks: a shared block is never written by two owners)
            src = hits[-1]
            self._acquire_cached(src)            # pin against eviction
            dst = self._alloc(1)[0]
            self.pool = _cow_copy(self.pool, jnp.asarray(src, jnp.int32),
                                  jnp.asarray(dst, jnp.int32))
            self._release([src])                 # drop the pin
            seq.append_block(dst)
            self.cow_copies += 1
            self.tracer.count("cow_copies")
            self.tracer.instant("prefix_cow", track="cache", cat="cache",
                                args={"src": src, "dst": dst})
            seq.cached_tokens = prompt_tokens - 1
        else:
            seq.cached_tokens = len(hits) * bs
        self.prefix_hits += 1
        self.prefix_tokens_reused += seq.cached_tokens
        self.tracer.count("prefix_hits")
        self.tracer.count("prefix_tokens_reused", seq.cached_tokens)
        self.tracer.instant("prefix_hit", track="cache", cat="cache",
                            args={"blocks": len(hits),
                                  "tokens": seq.cached_tokens})
        self.tracer.gauge("cached_blocks", self.allocator.n_cached)

    # ---------------------------------------------------------- lifecycle --
    def open_sequence(self, prompt_tokens: int, total_tokens: int,
                      token_ids=None) -> SequenceBlocks:
        """Admit a request: allocate prompt blocks now, reserve the rest so
        decode-time growth (`maybe_grow`) can never fail mid-flight. With
        the prefix cache on and ``token_ids`` given, consecutive full
        blocks matching the cache are SHARED instead of allocated —
        ``seq.cached_tokens`` positions are already resident and prefill
        may start there."""
        need = self.blocks_for(total_tokens)
        now = self.blocks_for(prompt_tokens)
        if need > self.n_free_unreserved or need > self.max_blocks_per_seq:
            raise OutOfBlocks(f"need {need} blocks, "
                              f"{self.n_free_unreserved} unreserved")
        seq = SequenceBlocks(
            table=np.zeros((self.max_blocks_per_seq,), np.int32),
            reserved=need)
        if self.prefix_cache and token_ids is not None and prompt_tokens > 0:
            assert len(token_ids) == prompt_tokens
            self._match_prefix(seq, token_ids, prompt_tokens)
        for b in self._alloc(now - len(seq.blocks)):
            seq.append_block(b)
        self._reserved_unheld += need - len(seq.blocks)
        return seq

    def grow_to(self, seq: SequenceBlocks, n_tokens: int) -> int:
        """Ensure ``seq`` owns blocks covering writes of its first
        ``n_tokens`` tokens — a whole fused decode window at once, so the
        device can scan several steps with no allocator round-trip. Draws on
        the admission-time reservation, so it cannot fail for any target
        within the admitted ``prompt + max_new_tokens`` budget. Returns the
        number of blocks allocated."""
        need = self.blocks_for(n_tokens)
        grown = 0
        while len(seq.blocks) < need:
            assert len(seq.blocks) < seq.reserved, "grew past reservation"
            seq.append_block(self._alloc(1)[0])
            self._reserved_unheld -= 1
            grown += 1
        return grown

    def maybe_grow(self, seq: SequenceBlocks) -> bool:
        """Before a decode step writing position ``seq.length``: allocate the
        next block if the write crosses a block boundary. Returns True if a
        block was allocated (block-granularity backfill signal)."""
        return self.grow_to(seq, seq.length + 1) > 0

    def truncate_to(self, seq: SequenceBlocks, n_tokens: int) -> int:
        """Token-level rollback (speculative decoding): keep only the blocks
        covering the first ``n_tokens`` accepted tokens and return every
        whole block past them to the free list, where in-flight growth of
        OTHER admitted sequences can reclaim them (new admissions still see
        them as promised). The freed blocks re-enter this sequence's
        admission-time reservation (``reserved`` is unchanged,
        ``_reserved_unheld`` grows by the freed count), so a later
        ``grow_to`` can always re-cover the rolled-back positions — rollback
        never strands a request mid-flight. Frees are block-granular:
        a partially-filled tail block is kept. Rolling back INTO the shared
        cached prefix is unsupported (accepted prefixes always cover the
        prompt, which covers the shared blocks)."""
        if n_tokens < seq.cached_tokens:
            raise ValueError(
                f"truncate_to({n_tokens}) would roll back into the shared "
                f"cached prefix ({seq.cached_tokens} tokens)")
        keep = 0 if n_tokens <= 0 else self.blocks_for(n_tokens)
        freed = seq.blocks[keep:]
        if freed:
            self.allocator.free(freed)
            del seq.blocks[keep:]
            seq.table[keep: keep + len(freed)] = 0
            self._reserved_unheld += len(freed)
        seq.length = min(seq.length, n_tokens)
        return len(freed)

    def close_sequence(self, seq: SequenceBlocks, token_ids=None) -> None:
        """Return the sequence's references. With the prefix cache on and
        the WRITTEN token stream given (prompt + generated tokens, length
        ``seq.length`` — KV position p holds the stream's p-th token in
        every serving mode), full blocks register under their chain hash
        and RETIRE into the cache (refcount 0 -> evictable LRU, contents
        retained) instead of freeing; the partial tail block and any block
        whose hash is already served by another physical block free
        normally."""
        if self.prefix_cache and token_ids is not None:
            n_full = min(seq.length, len(token_ids)) // self.block_size
            n_full = min(n_full, len(seq.blocks))
            for i, h in enumerate(self._chain_hashes(token_ids, n_full)):
                b = seq.blocks[i]
                if b in self._hash_of_block:
                    continue                     # shared hit: already indexed
                if h in self._block_of_hash:
                    continue                     # duplicate content: free it
                self._block_of_hash[h] = b
                self._hash_of_block[b] = h
        self._release(seq.blocks)
        self._reserved_unheld -= seq.reserved - len(seq.blocks)
        seq.blocks = []
        seq.reserved = 0
        seq.n_shared = 0
        seq.table[:] = 0
        self.allocator.check()

    def assert_drained(self) -> None:
        """Leak check after the scheduler drains: every block is back in the
        free list or parked refcount-0 in the prefix cache (reclaimable on
        demand — retention is not a leak), and no admission reservation is
        outstanding. Run by the scheduler fuzz/conformance tests after
        every arm."""
        self.allocator.check()
        held = (self.num_blocks - 1 - self.allocator.n_free
                - self.allocator.n_cached)
        assert held == 0, f"{held} pool blocks leaked after drain"
        assert self.allocator.n_cached == len(self._lru), (
            "cached blocks out of sync with the eviction LRU")
        assert self._reserved_unheld == 0, \
            f"{self._reserved_unheld} reserved-unheld blocks leaked"

    # ------------------------------------------------------------- stats --
    def memory_tokens(self) -> int:
        """Total token capacity of the pool (for equal-memory comparisons);
        the null block is real memory, so it counts."""
        return self.num_blocks * self.block_size

    def pool_bytes(self) -> int:
        """Device bytes held by the pool tensors — the equal-memory axis of
        the int8-KV capacity comparison (benchmarks/bench_quant.py): an int8
        pool stores ~2x the token slots of a bf16 pool of the same size."""
        return sum(int(a.size) * a.dtype.itemsize for a in self.pool.values())

    def utilization(self) -> float:
        held = (self.num_blocks - 1 - self.allocator.n_free
                - self.allocator.n_cached)
        return held / max(self.num_blocks - 1, 1)

    def prefix_stats(self) -> dict:
        """Prefix-cache counter snapshot (merged into PagedBatcher.stats)."""
        return {
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
            "cached_blocks": self.allocator.n_cached,
        }
