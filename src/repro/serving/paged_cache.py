"""Paged (block-table) KV cache: vLLM-style paging for the serving stack.

The paper's §3/§4.2 observation — unified-memory mobile SoCs are bound by
memory capacity and bandwidth, not compute — makes KV memory the scaling
lever for multi-request serving. The dense continuous batcher preallocates
``[max_batch, max_len]`` per slot, so one long request reserves worst-case
memory and concurrency is capped at ``max_batch`` regardless of actual
lengths. Here the cache is a shared pool of fixed-size token blocks:

  * pool tensors ``k``/``v``: ``[L, num_blocks, block_size, Hkv, D]``;
  * a host-side free-list :class:`BlockAllocator` hands blocks to requests;
  * each request owns a **block table** (``[max_blocks_per_seq]`` int32 of
    pool block ids) mapping logical token position ``t`` to physical slot
    ``table[t // block_size] * block_size + t % block_size``;
  * device reads gather pages with ``jnp.take`` and writes scatter through
    flat ``.at[idx].set`` — both fully jittable, so batched decode stays a
    single compiled graph.

Block id 0 is reserved as the **null block**: unused table entries point at
it, so gathers are always in-bounds (garbage there is masked positionally by
the causal mask, exactly how the dense path masks unwritten slots) and
inactive decode lanes harmlessly sink their writes into it.

Allocator invariants (asserted):
  * block 0 is never handed out and never freed;
  * a block is owned by at most one request at a time;
  * ``free + outstanding == num_blocks - 1`` at all times.

Growth is two-phase (``open_sequence`` reserves, ``grow_to`` draws on the
reservation) and reversible: ``truncate_to`` rolls a sequence back to an
accepted token prefix, returning whole blocks past it to the free list while
keeping them inside the reservation — the speculative-decoding rollback
primitive (serving/spec.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAllocator:
    """Free-list allocator over pool blocks ``1..num_blocks-1`` (0 = null)."""

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least one allocatable block"
        self.num_blocks = num_blocks
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self._owned: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"requested {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._owned.update(out)
        return out

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            assert b != 0, "null block must never be freed"
            assert b in self._owned, f"double free of block {b}"
            self._owned.remove(b)
            self._free.append(b)

    def check(self) -> None:
        assert len(self._free) + len(self._owned) == self.num_blocks - 1
        assert 0 not in self._owned and 0 not in self._free


@dataclass
class SequenceBlocks:
    """One request's view of the pool: its block table and write cursor."""
    table: np.ndarray                  # [max_blocks_per_seq] int32, 0-padded
    blocks: list = field(default_factory=list)   # allocated pool block ids
    length: int = 0                    # tokens written so far
    reserved: int = 0                  # blocks admission promised (incl. held)

    def append_block(self, block_id: int) -> None:
        self.table[len(self.blocks)] = block_id
        self.blocks.append(block_id)


class PagedKVCache:
    """Shared KV pool + allocator + per-request block tables.

    The device arrays live in ``self.pool`` (``{"k","v"}``, each
    ``[L, num_blocks, block_size, Hkv, D]``); scheduler code threads that
    dict through the jitted paged prefill/decode functions and stores the
    donated result back.
    """

    def __init__(self, cfg, *, num_blocks: int, block_size: int = 32,
                 max_blocks_per_seq: int | None = None, dtype=jnp.bfloat16):
        from repro.models import transformer
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blocks_per_seq = (max_blocks_per_seq
                                   if max_blocks_per_seq is not None
                                   else num_blocks - 1)
        self.pool = transformer.init_paged_cache(
            cfg, num_blocks=num_blocks, block_size=block_size, dtype=dtype)
        self.allocator = BlockAllocator(num_blocks)
        self._reserved_unheld = 0      # promised at admission, not yet alloc'd

    # ------------------------------------------------------------- sizing --
    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 1) / self.block_size)

    @property
    def n_free_unreserved(self) -> int:
        """Blocks available to NEW admissions (free minus outstanding IOUs)."""
        return self.allocator.n_free - self._reserved_unheld

    def can_admit(self, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens)
        return (need <= self.max_blocks_per_seq
                and need <= self.n_free_unreserved)

    # ---------------------------------------------------------- lifecycle --
    def open_sequence(self, prompt_tokens: int, total_tokens: int
                      ) -> SequenceBlocks:
        """Admit a request: allocate prompt blocks now, reserve the rest so
        decode-time growth (`maybe_grow`) can never fail mid-flight."""
        need = self.blocks_for(total_tokens)
        now = self.blocks_for(prompt_tokens)
        if need > self.n_free_unreserved or need > self.max_blocks_per_seq:
            raise OutOfBlocks(f"need {need} blocks, "
                              f"{self.n_free_unreserved} unreserved")
        seq = SequenceBlocks(
            table=np.zeros((self.max_blocks_per_seq,), np.int32),
            reserved=need)
        for b in self.allocator.alloc(now):
            seq.append_block(b)
        self._reserved_unheld += need - now
        return seq

    def grow_to(self, seq: SequenceBlocks, n_tokens: int) -> int:
        """Ensure ``seq`` owns blocks covering writes of its first
        ``n_tokens`` tokens — a whole fused decode window at once, so the
        device can scan several steps with no allocator round-trip. Draws on
        the admission-time reservation, so it cannot fail for any target
        within the admitted ``prompt + max_new_tokens`` budget. Returns the
        number of blocks allocated."""
        need = self.blocks_for(n_tokens)
        grown = 0
        while len(seq.blocks) < need:
            assert len(seq.blocks) < seq.reserved, "grew past reservation"
            seq.append_block(self.allocator.alloc(1)[0])
            self._reserved_unheld -= 1
            grown += 1
        return grown

    def maybe_grow(self, seq: SequenceBlocks) -> bool:
        """Before a decode step writing position ``seq.length``: allocate the
        next block if the write crosses a block boundary. Returns True if a
        block was allocated (block-granularity backfill signal)."""
        return self.grow_to(seq, seq.length + 1) > 0

    def truncate_to(self, seq: SequenceBlocks, n_tokens: int) -> int:
        """Token-level rollback (speculative decoding): keep only the blocks
        covering the first ``n_tokens`` accepted tokens and return every
        whole block past them to the free list, where in-flight growth of
        OTHER admitted sequences can reclaim them (new admissions still see
        them as promised). The freed blocks re-enter this sequence's
        admission-time reservation (``reserved`` is unchanged,
        ``_reserved_unheld`` grows by the freed count), so a later
        ``grow_to`` can always re-cover the rolled-back positions — rollback
        never strands a request mid-flight. Frees are block-granular:
        a partially-filled tail block is kept. Returns the number of blocks
        freed."""
        keep = 0 if n_tokens <= 0 else self.blocks_for(n_tokens)
        freed = seq.blocks[keep:]
        if freed:
            self.allocator.free(freed)
            del seq.blocks[keep:]
            seq.table[keep: keep + len(freed)] = 0
            self._reserved_unheld += len(freed)
        seq.length = min(seq.length, n_tokens)
        return len(freed)

    def close_sequence(self, seq: SequenceBlocks) -> None:
        self.allocator.free(seq.blocks)
        self._reserved_unheld -= seq.reserved - len(seq.blocks)
        seq.blocks = []
        seq.reserved = 0
        seq.table[:] = 0
        self.allocator.check()

    def assert_drained(self) -> None:
        """Leak check after the scheduler drains: every block is back in the
        free list and no admission reservation is outstanding. Run by the
        scheduler fuzz/conformance tests after every arm."""
        self.allocator.check()
        held = self.num_blocks - 1 - self.allocator.n_free
        assert held == 0, f"{held} pool blocks leaked after drain"
        assert self._reserved_unheld == 0, \
            f"{self._reserved_unheld} reserved-unheld blocks leaked"

    # ------------------------------------------------------------- stats --
    def memory_tokens(self) -> int:
        """Total token capacity of the pool (for equal-memory comparisons);
        the null block is real memory, so it counts."""
        return self.num_blocks * self.block_size

    def utilization(self) -> float:
        held = self.num_blocks - 1 - self.allocator.n_free
        return held / max(self.num_blocks - 1, 1)
