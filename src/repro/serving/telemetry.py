"""Request-lifecycle telemetry: injectable clocks, per-request traces,
and SLO percentile reports for the serving stack.

The paper's end-to-end claims (1.34x-6.02x) are statements about what a
USER sees — time-to-first-token and per-token decode latency under a real
request stream — not about dispatch counts. This module is the measuring
instrument: the ingress (serving/ingress.py) stamps every lifecycle event
of every request against an injectable :class:`Clock`, and
:class:`Telemetry` turns the stamps into the latency distribution the
serving benchmarks report.

Events per request (all optional except enqueue):

  enqueue  — the request ARRIVED (open-loop: the generator's scheduled
             arrival time, independent of whether the server was busy);
  admit    — the scheduler accepted it into the batcher (first admit only
             feeds queue-delay; re-admits after preemption are counted);
  token    — one output token reached the stream (the first one closes
             TTFT);
  preempt  — the scheduler evicted the request's KV mid-flight to free
             capacity (it re-enters the queue and re-admits later);
  finish   — the terminal event.

Derived metrics (reported in milliseconds):

  TTFT        = first_token - enqueue        (queueing + prefill)
  queue-delay = admit - enqueue              (pure scheduling delay)
  TPOT        = (last_token - first_token) / (n_tokens - 1)
                — the mean inter-token gap, EXCLUDING the first token, so
                TTFT never contaminates the decode-latency number;
  goodput     = finished requests meeting the TTFT SLO per second of
                makespan (all finished requests when no SLO is given).

Determinism contract: every number is a pure function of the recorded
timestamps. Under :class:`FakeClock` (manually advanced virtual time) the
same seeded workload produces bitwise-identical reports across runs — the
property the tier-1 tests pin. Production uses :class:`MonotonicClock`
(``time.monotonic``); nothing in this module ever calls ``time.sleep``.
"""
from __future__ import annotations

import asyncio
import math
import time
from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable


# ------------------------------------------------------------------ clocks --

@runtime_checkable
class Clock(Protocol):
    """Injectable time source: ``now()`` in seconds plus an async ``sleep``
    so the ingress can wait for the next scheduled arrival without blocking
    the event loop (or, under FakeClock, without waiting at all)."""

    def now(self) -> float: ...

    async def sleep(self, dt: float) -> None: ...


class MonotonicClock:
    """Production clock: ``time.monotonic`` timestamps, real async sleeps.
    Not manually advanceable — pairing it with a virtual per-step cost
    (``step_time_s``) is rejected by the ingress."""

    def now(self) -> float:
        return time.monotonic()

    async def sleep(self, dt: float) -> None:
        await asyncio.sleep(max(dt, 0.0))


class FakeClock:
    """Deterministic test clock: time only moves when the test (or the
    ingress's virtual step cost) says so. ``sleep`` advances instantly and
    yields once to the event loop, so awaiting consumers interleave exactly
    as they would under a real clock — with zero wall-clock dependence."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards ({dt})")
        self._t += dt

    async def sleep(self, dt: float) -> None:
        self.advance(max(dt, 0.0))
        await asyncio.sleep(0)        # cooperative yield, never a real wait


# ------------------------------------------------------------- percentiles --

def percentile(values, q: float) -> Optional[float]:
    """Linearly-interpolated percentile (numpy's default 'linear' method,
    implemented here so the math under test has no external moving parts):
    the q-th percentile sits at fractional rank ``(n-1) * q/100`` of the
    sorted values. Returns None on an empty input; a singleton is every
    percentile of itself."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(float(v) for v in values)
    if not xs:
        return None
    pos = (len(xs) - 1) * (q / 100.0)
    lo, hi = math.floor(pos), math.ceil(pos)
    if lo == hi:
        return xs[lo]
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(values) -> dict:
    """p50/p95/p99 + mean/max/n of a metric sample (None-filled when
    empty) — the fixed shape every latency row in a report takes."""
    xs = [float(v) for v in values]
    if not xs:
        return {"n": 0, "mean": None, "p50": None, "p95": None,
                "p99": None, "max": None}
    return {
        "n": len(xs),
        "mean": sum(xs) / len(xs),
        "p50": percentile(xs, 50.0),
        "p95": percentile(xs, 95.0),
        "p99": percentile(xs, 99.0),
        "max": max(xs),
    }


# ------------------------------------------------------------------ traces --

@dataclass
class RequestTrace:
    """One request's timestamped lifecycle (seconds, clock domain)."""
    rid: int
    priority: int = 0
    enqueue_t: float = 0.0
    admit_t: Optional[float] = None       # FIRST admit (queue-delay anchor)
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    n_tokens: int = 0
    token_ts: list = field(default_factory=list)
    preemptions: int = 0
    readmits: int = 0                     # admits after the first

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.enqueue_t

    @property
    def queue_delay(self) -> Optional[float]:
        if self.admit_t is None:
            return None
        # clamp: an admission in the same tick the arrival was released can
        # stamp admit_t one float ulp below the scheduled enqueue_t (the
        # clock reaches the same instant via a different summation order);
        # queueing delay is non-negative by definition
        return max(0.0, self.admit_t - self.enqueue_t)

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token time over tokens AFTER the first — TTFT (and
        therefore queueing + prefill) never leaks into the decode number.
        Undefined below two tokens."""
        if self.n_tokens < 2:
            return None
        return (self.last_token_t - self.first_token_t) / (self.n_tokens - 1)

    @property
    def finished(self) -> bool:
        return self.finish_t is not None


class Telemetry:
    """Event recorder: the ingress calls ``on_*`` as lifecycle events
    happen; ``report()`` folds the traces into the percentile dict the
    benchmarks emit. Timestamps default to ``clock.now()`` but every hook
    takes an explicit ``at=`` so open-loop arrivals can be stamped at their
    SCHEDULED time even when the server notices them late (that lateness is
    exactly the queueing the metric must see)."""

    def __init__(self, clock: Clock | None = None):
        self.clock: Clock = clock if clock is not None else MonotonicClock()
        self.traces: dict[int, RequestTrace] = {}

    # ------------------------------------------------------------- events --
    def _at(self, at: Optional[float]) -> float:
        return self.clock.now() if at is None else float(at)

    def _trace(self, rid: int) -> RequestTrace:
        try:
            return self.traces[rid]
        except KeyError:
            raise KeyError(f"request {rid} was never enqueued") from None

    def on_enqueue(self, rid: int, *, priority: int = 0,
                   at: Optional[float] = None) -> RequestTrace:
        if rid in self.traces:
            raise ValueError(f"request {rid} already enqueued")
        tr = RequestTrace(rid=rid, priority=priority, enqueue_t=self._at(at))
        self.traces[rid] = tr
        return tr

    def on_admit(self, rid: int, at: Optional[float] = None) -> None:
        tr = self._trace(rid)
        if tr.admit_t is None:
            tr.admit_t = self._at(at)
        else:
            tr.readmits += 1             # resume after preemption

    def on_token(self, rid: int, at: Optional[float] = None) -> None:
        tr = self._trace(rid)
        t = self._at(at)
        if tr.first_token_t is None:
            tr.first_token_t = t
        tr.last_token_t = t
        tr.n_tokens += 1
        tr.token_ts.append(t)

    def on_preempt(self, rid: int, at: Optional[float] = None) -> None:
        self._trace(rid).preemptions += 1
        del at                            # preemption is a count, not a stamp

    def on_finish(self, rid: int, at: Optional[float] = None) -> None:
        tr = self._trace(rid)
        if tr.finish_t is not None:
            raise ValueError(f"request {rid} finished twice")
        tr.finish_t = self._at(at)

    # ------------------------------------------------------------- report --
    def report(self, slo_ms: Optional[float] = None) -> dict:
        """Aggregate the traces: TTFT / TPOT / queue-delay summaries in
        MILLISECONDS, throughput, and goodput against an optional TTFT SLO.
        A pure function of the recorded stamps — same events, same bits."""
        trs = list(self.traces.values())
        done = [t for t in trs if t.finished]
        ms = 1e3
        rep = {
            "n_requests": len(trs),
            "n_finished": len(done),
            "n_tokens": sum(t.n_tokens for t in trs),
            "preemptions": sum(t.preemptions for t in trs),
            "ttft_ms": summarize([t.ttft * ms for t in trs
                                  if t.ttft is not None]),
            "tpot_ms": summarize([t.tpot * ms for t in trs
                                  if t.tpot is not None]),
            "queue_delay_ms": summarize([t.queue_delay * ms for t in trs
                                         if t.queue_delay is not None]),
        }
        if done:
            t0 = min(t.enqueue_t for t in trs)
            t1 = max(t.finish_t for t in done)
            makespan = t1 - t0
            rep["makespan_s"] = makespan
            rep["throughput_tok_s"] = (
                sum(t.n_tokens for t in done) / makespan if makespan > 0
                else None)
            good = [t for t in done
                    if slo_ms is None
                    or (t.ttft is not None and t.ttft * ms <= slo_ms)]
            rep["slo_ms"] = slo_ms
            rep["slo_attainment"] = len(good) / len(done)
            rep["goodput_req_s"] = (len(good) / makespan if makespan > 0
                                    else None)
        else:
            rep.update({"makespan_s": None, "throughput_tok_s": None,
                        "slo_ms": slo_ms, "slo_attainment": None,
                        "goodput_req_s": None})
        return rep
