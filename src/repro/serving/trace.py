"""Serving-wide structured tracing, metrics exposition, and solver
plan-vs-actual drift accounting.

The paper's whole design is a characterize -> plan loop: profiled
per-processor costs drive the ``PartitionSolver``'s per-(site, M) strategy
decisions. Until now the loop was OPEN — the engine never observed whether
the predicted ``t_us`` numbers match what dispatches actually cost at
runtime, and the serving stack (ingress -> scheduler -> fused windows ->
spec rounds) exposed only aggregate ``stats()`` counters. This module
closes it with three instruments behind one object:

  * :class:`Tracer` — a ring-buffered span/event recorder on the serving
    stack's injectable :class:`~repro.serving.telemetry.Clock`. Every
    request lifecycle event (enqueue/admit/preempt/resume/finish, with
    per-request flow arrows), every dispatch (prefill chunk, decode step,
    fused decode window, mixed step, spec draft round, ``paged_verify``)
    and every prefix-cache event (hit/CoW/evict) becomes a structured
    event, tagged with the solver decision that planned it (site, M,
    strategy, predicted ``t_us``). Exported as Chrome trace-event JSON
    (:meth:`Tracer.to_chrome` — per-lane tracks, Perfetto-loadable) and a
    Prometheus-style text snapshot (:meth:`Tracer.to_prometheus`).
  * :class:`MetricsRegistry` — counters / gauges / histograms whose
    counter names deliberately MATCH the schedulers' ``stats()`` keys, so
    the two accounting systems reconcile exactly
    (:func:`counter_reconciliation` — pinned by the fuzz cross-check arm).
  * :class:`DriftAggregator` — measured dispatch durations attributed per
    (site, M, strategy) against the solver's predictions: the plan-drift
    report emits predicted-vs-observed residuals and flags decisions whose
    measured ordering contradicts the plan (the would-have-been-faster
    alternative) — the observe edge that closes characterize -> plan ->
    observe.

Determinism contract: the tracer never reads wall-clock time itself —
every timestamp comes from the injected clock, and the tracer never
sleeps. Under :class:`~repro.serving.telemetry.FakeClock` (the tier-1
regime) identical runs produce BYTE-identical trace artifacts
(:meth:`Tracer.save_chrome` serializes with sorted keys and fixed
separators). An optional ``cost_model`` hook advances an advanceable clock
by a deterministic virtual duration inside each dispatch span, so traced
virtual-time runs get nonzero, reproducible span durations (and therefore
nonzero drift residuals) without a single real timer.

Zero-overhead-when-off contract: schedulers default to the shared
:data:`NULL_TRACER` singleton, whose every method is a no-op returning a
reusable null context — no event is ever recorded, no prediction is ever
looked up (call sites guard tag computation on ``tracer.enabled``), and
behavior is bit-identical to the uninstrumented stack.
"""
from __future__ import annotations

import json
from collections import deque
from contextlib import contextmanager
from pathlib import Path

from .telemetry import Clock, MonotonicClock

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "MetricsRegistry",
    "DriftAggregator", "counter_reconciliation",
    "STATS_COUNTER_KEYS", "STATS_GAUGE_KEYS",
]

_PID = 1        # one serving process per trace

# stats() keys that are mirrored 1:1 by tracer counters/gauges: whenever a
# scheduler/ingress/pool python counter moves, the tracer counter of the
# SAME name moves with it. counter_reconciliation() asserts the two ledgers
# agree exactly — the contract the fuzz cross-check arm pins on every arm.
STATS_COUNTER_KEYS = (
    "decode_dispatches", "decode_steps", "prefill_dispatches", "fused_steps",
    "preemptions", "spec_rounds", "drafted_tokens", "accepted_tokens",
    "verify_dispatches", "draft_dispatches",
    "prefix_hits", "prefix_tokens_reused", "evictions", "cow_copies",
    "ingress_ticks", "ingress_preemptions", "ingress_deferrals",
)
STATS_GAUGE_KEYS = ("peak_active", "cached_blocks")

# histogram bucket upper bounds, microseconds (dispatch durations)
DEFAULT_BUCKETS_US = (50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
                      10000.0, 25000.0, 50000.0, 100000.0)


def _fmt_num(v) -> str:
    """Stable numeric rendering: integral values print as ints, the rest
    as ``repr(float)`` — same value, same bytes, every run."""
    fv = float(v)
    return str(int(fv)) if fv.is_integer() else repr(fv)


# ----------------------------------------------------------------- metrics --

class MetricsRegistry:
    """Counters, gauges and histograms with optional labels, rendered as a
    Prometheus-style text snapshot. All keys are (name, sorted-label-tuple);
    rendering is fully sorted, so equal contents always produce equal
    bytes."""

    def __init__(self, buckets=DEFAULT_BUCKETS_US):
        self.buckets = tuple(sorted(buckets))
        self._counters: dict = {}      # (name, labels) -> float
        self._gauges: dict = {}        # (name, labels) -> float
        # (name, labels) -> [per-bucket counts..., overflow], sum, count
        self._hists: dict = {}

    @staticmethod
    def _key(name, labels):
        return name, tuple(sorted(labels.items()))

    def count(self, name: str, n=1, **labels) -> None:
        key = self._key(name, labels)
        self._counters[key] = self._counters.get(key, 0) + n

    def gauge(self, name: str, value, **labels) -> None:
        self._gauges[self._key(name, labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        key = self._key(name, labels)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = {"counts": [0] * (len(self.buckets) + 1),
                                    "sum": 0.0, "count": 0}
        v = float(value)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                h["counts"][i] += 1
                break
        else:
            h["counts"][-1] += 1       # overflow (+Inf bucket)
        h["sum"] += v
        h["count"] += 1

    def value(self, name: str, **labels):
        """Current counter-or-gauge value (0 when never touched)."""
        key = self._key(name, labels)
        if key in self._counters:
            return self._counters[key]
        return self._gauges.get(key, 0)

    # ------------------------------------------------------------ render --
    @staticmethod
    def _labels(items, extra=()) -> str:
        items = list(items) + list(extra)
        if not items:
            return ""
        return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"

    def to_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition: ``# HELP``/``# TYPE`` headers,
        counters suffixed ``_total``, histograms as cumulative
        ``_bucket{le=...}`` series plus ``_sum``/``_count``. Deterministic:
        metric names and label sets render sorted."""
        lines: list[str] = []
        for name in sorted({n for (n, _) in self._counters}):
            fq = f"{prefix}{name}_total"
            lines += [f"# HELP {fq} {name} (counter)",
                      f"# TYPE {fq} counter"]
            for (n, labels), v in sorted(self._counters.items()):
                if n == name:
                    lines.append(f"{fq}{self._labels(labels)} {_fmt_num(v)}")
        for name in sorted({n for (n, _) in self._gauges}):
            fq = f"{prefix}{name}"
            lines += [f"# HELP {fq} {name} (gauge)", f"# TYPE {fq} gauge"]
            for (n, labels), v in sorted(self._gauges.items()):
                if n == name:
                    lines.append(f"{fq}{self._labels(labels)} {_fmt_num(v)}")
        for name in sorted({n for (n, _) in self._hists}):
            fq = f"{prefix}{name}"
            lines += [f"# HELP {fq} {name} (histogram)",
                      f"# TYPE {fq} histogram"]
            for (n, labels), h in sorted(self._hists.items()):
                if n != name:
                    continue
                cum = 0
                for ub, c in zip(self.buckets, h["counts"]):
                    cum += c
                    lines.append(
                        f"{fq}_bucket"
                        f"{self._labels(labels, [('le', _fmt_num(ub))])}"
                        f" {cum}")
                cum += h["counts"][-1]
                lines.append(f"{fq}_bucket"
                             f"{self._labels(labels, [('le', '+Inf')])}"
                             f" {cum}")
                lines.append(f"{fq}_sum{self._labels(labels)}"
                             f" {_fmt_num(h['sum'])}")
                lines.append(f"{fq}_count{self._labels(labels)}"
                             f" {h['count']}")
        return "\n".join(lines) + "\n"


# ------------------------------------------------------------- plan drift --

class DriftAggregator:
    """Predicted-vs-observed accounting per solver decision.

    Each traced dispatch attributes its measured duration across the
    decisions that planned it, proportionally to each decision's predicted
    share (``t_us x count``, where count folds in per-layer repetition and
    window steps); :meth:`record` accumulates (n, predicted, observed) per
    (site, M, strategy) key. :meth:`report` emits one residual row per
    decision exercised, plus CONTRADICTIONS: (site, M) keys where the
    strategy measured fastest is not the strategy predicted fastest — the
    would-have-been-faster alternative the plan missed."""

    def __init__(self):
        self._acc: dict = {}     # (site, M, strategy) -> [n, pred_us, obs_us]

    def record(self, site: str, M: int, strategy: str, *,
               predicted_us: float, observed_us: float) -> None:
        key = (site, int(M), strategy)
        a = self._acc.get(key)
        if a is None:
            a = self._acc[key] = [0, 0.0, 0.0]
        a[0] += 1
        a[1] += float(predicted_us)
        a[2] += float(observed_us)

    @property
    def n_decisions(self) -> int:
        return len(self._acc)

    def report(self) -> dict:
        rows = []
        for (site, M, strat), (n, ps, os_) in sorted(self._acc.items()):
            pred, obs = ps / n, os_ / n
            rows.append({
                "site": site, "M": M, "strategy": strat, "n": n,
                "predicted_us": pred, "observed_us": obs,
                "residual_us": obs - pred,
                "ratio": (obs / pred) if pred > 0 else None,
            })
        by_sm: dict = {}
        for r in rows:
            by_sm.setdefault((r["site"], r["M"]), []).append(r)
        contradictions = []
        for (site, M), group in sorted(by_sm.items()):
            if len(group) < 2:
                continue            # one strategy observed: no ordering to test
            planned = min(group, key=lambda r: r["predicted_us"])
            fastest = min(group, key=lambda r: r["observed_us"])
            if planned["strategy"] != fastest["strategy"]:
                contradictions.append({
                    "site": site, "M": M,
                    "planned": planned["strategy"],
                    "planned_predicted_us": planned["predicted_us"],
                    "planned_observed_us": planned["observed_us"],
                    "faster": fastest["strategy"],
                    "faster_observed_us": fastest["observed_us"],
                })
        return {"rows": rows, "contradictions": contradictions}

    def format_table(self) -> str:
        """Human-readable plan-drift table (what ``serve.py --plan-drift``
        prints)."""
        rep = self.report()
        if not rep["rows"]:
            return ("plan-drift: no solver-tagged dispatches recorded "
                    "(run with --engine-mode to attach a plan)")
        lines = [f"{'site':<10} {'M':>6} {'strategy':<10} {'n':>5} "
                 f"{'pred_us':>10} {'obs_us':>10} {'resid_us':>10} "
                 f"{'obs/pred':>8}"]
        for r in rep["rows"]:
            ratio = f"{r['ratio']:.3f}" if r["ratio"] is not None else "-"
            lines.append(
                f"{r['site']:<10} {r['M']:>6} {r['strategy']:<10} "
                f"{r['n']:>5} {r['predicted_us']:>10.1f} "
                f"{r['observed_us']:>10.1f} {r['residual_us']:>+10.1f} "
                f"{ratio:>8}")
        for c in rep["contradictions"]:
            lines.append(
                f"CONTRADICTION {c['site']}[M={c['M']}]: plan chose "
                f"{c['planned']} ({c['planned_observed_us']:.1f}us observed)"
                f" but {c['faster']} measured faster "
                f"({c['faster_observed_us']:.1f}us)")
        lines.append(f"({len(rep['rows'])} decision rows, "
                     f"{len(rep['contradictions'])} contradictions)")
        return "\n".join(lines)


# ------------------------------------------------------------- the tracer --

class _NullCtx:
    """Reusable no-op context manager (the disabled-tracer span)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class NullTracer:
    """The default: every hook is a no-op. ``enabled`` is the guard call
    sites use to skip tag/prediction computation entirely, so an
    uninstrumented run does no extra work and records nothing."""
    enabled = False

    def span(self, *a, **k):
        return _NULL_CTX

    def dispatch(self, *a, **k):
        return _NULL_CTX

    def instant(self, *a, **k):
        pass

    def request_event(self, *a, **k):
        pass

    def count(self, *a, **k):
        pass

    def gauge(self, *a, **k):
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Ring-buffered structured tracer on an injectable clock.

    ``capacity`` bounds the event buffer (oldest events drop first;
    ``dropped`` counts them — bounded memory under open-loop load).
    ``cost_model(kind, predicted_us) -> seconds``, when given together
    with an advanceable clock (FakeClock), charges a deterministic virtual
    duration inside every dispatch span — the mechanism that gives tier-1
    traces nonzero, bitwise-reproducible durations with zero real timers.
    Metric counters whose names appear in :data:`STATS_COUNTER_KEYS` are
    incremented by the instrumented call sites in lockstep with the
    schedulers' python counters (the reconciliation contract)."""
    enabled = True

    def __init__(self, clock: Clock | None = None, *,
                 capacity: int = 65536, cost_model=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.clock: Clock = clock if clock is not None else MonotonicClock()
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self.n_events = 0              # emitted ever (retained + dropped)
        self.metrics = MetricsRegistry()
        self.drift = DriftAggregator()
        self.cost_model = cost_model
        self._tracks: dict[str, int] = {}   # track name -> integer tid

    # ---------------------------------------------------------- plumbing --
    @property
    def events(self) -> list[dict]:
        return list(self._buf)

    @property
    def dropped(self) -> int:
        return self.n_events - len(self._buf)

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks) + 1
        return tid

    def _ts(self, at=None) -> int:
        t = self.clock.now() if at is None else float(at)
        return int(round(t * 1e6))     # Chrome trace ts are microseconds

    def _emit(self, ev: dict) -> None:
        self._buf.append(ev)
        self.n_events += 1

    # ------------------------------------------------------------ events --
    @contextmanager
    def span(self, name: str, *, track: str = "scheduler",
             cat: str = "span", args: dict | None = None):
        """A paired B/E duration event on ``track``."""
        tid = self._tid(track)
        self._emit({"name": name, "ph": "B", "ts": self._ts(), "pid": _PID,
                    "tid": tid, "cat": cat, "args": args or {}})
        try:
            yield
        finally:
            self._emit({"name": name, "ph": "E", "ts": self._ts(),
                        "pid": _PID, "tid": tid, "cat": cat, "args": {}})

    @contextmanager
    def dispatch(self, kind: str, *, track: str = "scheduler", tags=(),
                 predicted_us: float = 0.0, args: dict | None = None):
        """A dispatch span: B/E pair carrying the solver decisions that
        planned it. On exit the measured duration lands in the
        ``dispatch_us`` histogram (labeled by kind) and is attributed
        across ``tags`` — ``(site, M, strategy, t_us, count)`` tuples —
        into the drift aggregator, proportionally to predicted share."""
        tid = self._tid(track)
        a = dict(args or {})
        if tags:
            a["decisions"] = [
                {"site": s, "M": m, "strategy": st, "t_us": t, "count": c}
                for (s, m, st, t, c) in tags]
            a["predicted_us"] = predicted_us
        t0 = self._ts()
        self._emit({"name": kind, "ph": "B", "ts": t0, "pid": _PID,
                    "tid": tid, "cat": "dispatch", "args": a})
        try:
            yield
        finally:
            if self.cost_model is not None \
                    and hasattr(self.clock, "advance"):
                self.clock.advance(
                    max(float(self.cost_model(kind, predicted_us)), 0.0))
            t1 = self._ts()
            self._emit({"name": kind, "ph": "E", "ts": t1, "pid": _PID,
                        "tid": tid, "cat": "dispatch", "args": {}})
            dur = float(t1 - t0)
            self.metrics.count("dispatches", kind=kind)
            self.metrics.observe("dispatch_us", dur, kind=kind)
            total = sum(t * c for (_, _, _, t, c) in tags)
            if total > 0:
                for (s, m, st, t, c) in tags:
                    self.drift.record(
                        s, m, st, predicted_us=t * c,
                        observed_us=dur * (t * c) / total)

    def instant(self, name: str, *, track: str = "scheduler",
                cat: str = "event", args: dict | None = None,
                at=None) -> None:
        self._emit({"name": name, "ph": "i", "ts": self._ts(at),
                    "pid": _PID, "tid": self._tid(track), "cat": cat,
                    "s": "t", "args": args or {}})

    def request_event(self, phase: str, rid: int, *,
                      track: str = "requests", args: dict | None = None,
                      at=None) -> None:
        """One request-lifecycle event (enqueue/admit/resume/preempt/
        finish): an instant on the requests track plus a Chrome flow event
        (``s`` at enqueue, ``t`` mid-life, ``f`` at finish, id = rid) so
        Perfetto draws the per-request arrow across tracks."""
        ts = self._ts(at)
        tid = self._tid(track)
        a = {"rid": rid}
        if args:
            a.update(args)
        self._emit({"name": phase, "ph": "i", "ts": ts, "pid": _PID,
                    "tid": tid, "cat": "request", "s": "t", "args": a})
        ph = {"enqueue": "s", "finish": "f"}.get(phase, "t")
        flow = {"name": "req", "ph": ph, "ts": ts, "pid": _PID, "tid": tid,
                "cat": "request", "id": int(rid), "args": {}}
        if ph == "f":
            flow["bp"] = "e"
        self._emit(flow)

    # ----------------------------------------------------------- metrics --
    def count(self, name: str, n=1, **labels) -> None:
        self.metrics.count(name, n, **labels)

    def gauge(self, name: str, value, **labels) -> None:
        self.metrics.gauge(name, value, **labels)

    def counter_value(self, name: str, **labels):
        return self.metrics.value(name, **labels)

    # ------------------------------------------------------------ export --
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object: thread-name metadata per track
        (integer tids — string tids don't render reliably), then the
        retained events STABLE-sorted by timestamp. Emission order alone is
        not monotone: open-loop arrivals are stamped at their SCHEDULED
        time (the telemetry contract), which can precede events already
        emitted by the tick that released them. The stable sort restores
        file-order monotonicity (scripts/check_trace.py's invariant) while
        ties keep emission order, so B/E nesting is preserved."""
        meta = [{"name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                 "args": {"name": track}}
                for track, tid in self._tracks.items()]
        return {
            "traceEvents": meta + sorted(self._buf,
                                         key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped,
                          "total_events": self.n_events},
        }

    def save_chrome(self, path) -> Path:
        """Serialize :meth:`to_chrome` byte-deterministically (sorted keys,
        fixed separators): equal traces are equal FILES."""
        p = Path(path)
        p.write_text(json.dumps(self.to_chrome(), sort_keys=True,
                                separators=(",", ":")) + "\n")
        return p

    def to_prometheus(self, prefix: str = "repro_") -> str:
        return self.metrics.to_prometheus(prefix)

    def save_prometheus(self, path, prefix: str = "repro_") -> Path:
        p = Path(path)
        p.write_text(self.to_prometheus(prefix))
        return p


# ----------------------------------------------------------- reconciliation --

def counter_reconciliation(tracer, stats: dict) -> dict:
    """Compare a scheduler/ingress ``stats()`` snapshot against the
    tracer's mirrored counters/gauges. Returns ``{key: (stats_value,
    tracer_value)}`` for every mismatch — empty means the two ledgers agree
    exactly (the contract the fuzz cross-check arm asserts). Keys in
    ``stats`` that have no tracer mirror (ratios, names, totals) are
    ignored; mirrored keys missing from the tracer compare against 0, so a
    forgotten increment can't hide."""
    mismatches = {}
    for key in STATS_COUNTER_KEYS + STATS_GAUGE_KEYS:
        if key not in stats:
            continue
        sv, tv = stats[key], tracer.counter_value(key)
        if sv != tv:
            mismatches[key] = (sv, tv)
    return mismatches
