"""Performance profiler (paper §4.4, "Performance Profiler").

Builds a LatencyTable: (site weight shape [K,N]) x (token count M) x (path)
-> latency us. Two modes:

  * ``analytic``  — evaluates the TPU characteristics models (the deploy-time
    default here: the container has no TPU, and the models encode the
    measured v5e behavior the kernels are built around).
  * ``measured``  — wall-clock microbenchmarks of the two real paths (XLA jnp
    matmul vs the Pallas MXU-path kernel) on the current backend. Used by the
    CPU benchmarks to demonstrate the *mechanism* end-to-end.

The profiling space is constrained exactly as in the paper: only the LLM's
weight shapes; token counts restricted to the standard bucket grid + probes
below/above each bucket edge. A full table profiles in seconds (paper: <20min
on-device).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable

import numpy as np

from .characteristics import (WEIGHT_BYTES_PER_EL, TPUSpec, V5E,
                              mxu_matmul_time_us, xla_matmul_time_us)

STANDARD_BUCKETS = (128, 256, 512, 1024, 2048, 4096)
PROBE_MS = (1, 8, 32, 64, 96, 128, 192, 256, 320, 384, 512, 768, 1024,
            1536, 2048, 3072, 4096)


def model_weight_shapes(cfg) -> dict[str, tuple[int, int]]:
    """Site name -> (K, N) for every partitionable matmul in the model."""
    d, hd = cfg.d_model, cfg.head_dim
    sites = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
        "head": (d, cfg.vocab_size),
    }
    if cfg.moe:
        sites.update({
            "w_gate": (d, cfg.moe.d_ff_expert),
            "w_up": (d, cfg.moe.d_ff_expert),
            "w_down": (cfg.moe.d_ff_expert, d),
        })
        if cfg.moe.d_ff_shared:
            sites.update({
                "shared/w_gate": (d, cfg.moe.d_ff_shared),
                "shared/w_up": (d, cfg.moe.d_ff_shared),
                "shared/w_down": (cfg.moe.d_ff_shared, d),
            })
    else:
        sites.update({
            "w_gate": (d, cfg.d_ff),
            "w_up": (d, cfg.d_ff),
            "w_down": (cfg.d_ff, d),
        })
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * d
        nh = d_in // cfg.ssm.head_dim
        sites["in_proj"] = (d, 2 * d_in + 2 * cfg.ssm.d_state + nh)
        sites["out_proj"] = (d_in, d)
    if cfg.rwkv is not None:
        sites = {"wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d),
                 "wo": (d, d), "wk_ffn": (d, cfg.d_ff), "wv_ffn": (cfg.d_ff, d),
                 "wr_ffn": (d, d), "head": (d, cfg.vocab_size)}
    return sites


@dataclass
class LatencyTable:
    """entries[(site, M, path)] = microseconds. path in {'mxu','xla'}."""
    spec: TPUSpec = V5E
    entries: dict = field(default_factory=dict)
    sites: dict = field(default_factory=dict)
    mode: str = "analytic"
    weight_quant: str | None = None   # None | "int8" | "w4a16" (storage dtype)

    def lookup(self, site: str, M: int, path: str) -> float:
        key = (site, M, path)
        if key in self.entries:
            return self.entries[key]
        return self.interpolate(site, M, path)

    def interpolate(self, site: str, M: int, path: str) -> float:
        """GPU-1 linear / NPU-1 stage interpolation for unseen M (paper §4.4:
        'the solver estimates operator latency for variable-length sequences
        by leveraging GPU-1 and NPU-1')."""
        ms = sorted({m for (s, m, p) in self.entries if s == site and p == path})
        if not ms:
            K, N = self.sites[site]
            f = mxu_matmul_time_us if path == "mxu" else xla_matmul_time_us
            return f(M, K, N, self.spec,
                     w_bytes_per_el=WEIGHT_BYTES_PER_EL[self.weight_quant])
        if path == "mxu":
            # stage model: latency of the next bucketed M (staircase)
            m_up = next((m for m in ms if m >= M), ms[-1])
            scale = 1.0 if m_up >= M else M / ms[-1]
            return self.entries[(site, m_up, path)] * max(scale, 1.0)
        # linear model through the two nearest points
        lo = max((m for m in ms if m <= M), default=ms[0])
        hi = next((m for m in ms if m >= M), ms[-1])
        tlo, thi = self.entries[(site, lo, path)], self.entries[(site, hi, path)]
        if hi == lo:
            return tlo * M / lo
        w = (M - lo) / (hi - lo)
        return tlo + w * (thi - tlo)

    def save(self, path: str | Path):
        data = {"mode": self.mode, "spec": self.spec.name,
                "weight_quant": self.weight_quant,
                "sites": {k: list(v) for k, v in self.sites.items()},
                "entries": [[s, m, p, t] for (s, m, p), t in self.entries.items()]}
        Path(path).write_text(json.dumps(data))

    @classmethod
    def load(cls, path: str | Path, spec: TPUSpec = V5E) -> "LatencyTable":
        data = json.loads(Path(path).read_text())
        t = cls(spec=spec, mode=data["mode"],
                weight_quant=data.get("weight_quant"))
        t.sites = {k: tuple(v) for k, v in data["sites"].items()}
        for s, m, p, v in data["entries"]:
            t.entries[(s, int(m), p)] = float(v)
        return t


def profile_analytic(cfg, spec: TPUSpec = V5E,
                     Ms: Iterable[int] = PROBE_MS,
                     *, weight_quant: str | None = None) -> LatencyTable:
    """``weight_quant`` shrinks the weight-stream bytes-per-element (int8 ->
    1 B, w4a16 -> 0.5 B): the memory-bound decode entries drop while the
    compute-bound prefill entries barely move, which is exactly the roofline
    shift the solver re-plans around."""
    wb = WEIGHT_BYTES_PER_EL[weight_quant]
    table = LatencyTable(spec=spec, mode="analytic", weight_quant=weight_quant)
    table.sites = model_weight_shapes(cfg)
    for site, (K, N) in table.sites.items():
        for M in Ms:
            table.entries[(site, M, "mxu")] = mxu_matmul_time_us(
                M, K, N, spec, w_bytes_per_el=wb)
            table.entries[(site, M, "xla")] = xla_matmul_time_us(
                M, K, N, spec, w_bytes_per_el=wb)
    return table


def profile_measured(cfg, Ms: Iterable[int] = (1, 32, 128, 256, 512),
                     *, repeats: int = 3, max_kn: int = 4096) -> LatencyTable:
    """Wall-clock the two real paths on the current backend (CPU here).
    Weight dims are capped so CPU profiling stays fast; relative path behavior
    (staircase vs linear) is what the benchmarks demonstrate."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.hetero_matmul.ops import mxu_matmul

    from .sync import fence

    table = LatencyTable(mode="measured")
    table.sites = {s: (min(k, max_kn), min(n, max_kn))
                   for s, (k, n) in model_weight_shapes(cfg).items()}
    rng = jax.random.PRNGKey(0)

    def bench(fn, *args):
        fence(fn(*args))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()  # repolint: disable=determinism -- profile_measured IS the paper's characterize step: it wall-clocks the real backend to build the latency table
            fence(fn(*args))
            ts.append(time.perf_counter() - t0)  # repolint: disable=determinism -- second read of the same characterization timer
        return float(np.median(ts) * 1e6)

    xla_mm = jax.jit(lambda a, b: a @ b)
    for site, (K, N) in table.sites.items():
        w = jax.random.normal(rng, (K, N), jnp.float32)
        for M in Ms:
            x = jax.random.normal(rng, (M, K), jnp.float32)
            table.entries[(site, M, "xla")] = bench(xla_mm, x, w)
            Mp = -(-M // 128) * 128      # MXU path needs aligned static shape
            xp = jax.random.normal(rng, (Mp, K), jnp.float32)
            if K % 128 == 0 and N % 128 == 0:
                table.entries[(site, M, "mxu")] = bench(
                    lambda a, b: mxu_matmul(a, b), xp, w)
    return table
