"""Fast synchronization (paper §4.3), TPU-native.

The paper's problem: host-driver sync (clFinish ~400us) between every
GPU/NPU kernel dwarfs decode kernels. The JAX analogue is the host-stepped
decode loop: one dispatch + block_until_ready + host round-trip per token.
The fix is the same idea as the paper's shared-buffer flag polling — keep
the whole loop on device:

  * ``generate_on_device``  — a single jitted ``lax.scan`` over decode steps
    with donated cache buffers: zero host round-trips ("fast sync").
  * ``generate_host_loop``  — the baseline: one jitted decode_step per token,
    host-synced each step (the clFinish analogue). ``hard_sync=True`` adds a
    device->host token fetch per step (the worst case the paper measures).
  * ``paged_decode_window`` — the paged-serving analogue: one jitted scan
    running a fixed WINDOW of batched paged decode steps per dispatch
    (scatter cache writes inside the scan, donated pool buffers), so the
    scheduler pays one host round-trip per window instead of per token.
    Mid-window termination (per-lane token budget or EOS) is handled by
    masking: a finished lane's block table is swapped to the null table and
    its length to 0, so its writes sink into the pool's null block exactly
    like an inactive lane. A window can additionally CARRY an in-flight
    prefill chunk (stage-parallel mixed batching, §4.1/§4.2): the first
    step of the window runs ``model.mixed_step`` — every decode lane plus
    one aligned prefill chunk of an admitting request in the same graph —
    so admission rides along a decode dispatch instead of stalling it.

``measure_dispatch_overhead`` quantifies the per-dispatch cost on the current
backend — the number the solver uses as T_sync in 'host' mode.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from functools import lru_cache, partial

import jax
import jax.numpy as jnp


def fence(*values):
    """The repo's ONE sanctioned host-side synchronization point: block
    until ``values`` are resolved on device, and return them unchanged.

    Every library-side ``block_until_ready`` routes through here (repolint's
    host-sync rule enforces it), so grepping for ``fence(`` enumerates all
    planned sync sites — the discipline the paper's §4.3 argues for. Returns
    the single value un-tupled for the common one-arg case."""
    for v in values:
        jax.block_until_ready(v)
    return values[0] if len(values) == 1 else values


def _greedy(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


@partial(jax.jit, static_argnames=("decode_step", "n_steps"), donate_argnums=(2,))
def _device_loop(params, first_token, cache, *, decode_step, n_steps: int):
    def step(carry, _):
        token, cache = carry
        logits, cache = decode_step(params, token, cache)
        nxt = _greedy(logits)
        return (nxt, cache), nxt[:, 0]

    (_, cache), toks = jax.lax.scan(step, (first_token, cache), None,
                                    length=n_steps)
    return toks.T, cache        # [B, n_steps]


def generate_on_device(model, params, first_token, cache, n_steps: int):
    """Fast-sync path: the entire decode loop is one device program."""
    return _device_loop(params, first_token, cache,
                        decode_step=model.decode_step, n_steps=n_steps)


def _masked_step(run, carry, key, *, block_tables, sampler, eos_id):
    """One masked batched decode step shared by the pure and mixed windows.

    ``run(token, eff_tables, eff_lengths, pool) -> (logits, extra, pool)``
    is the step body (plain paged decode, or a mixed decode+prefill step
    whose ``extra`` is the prefill-chunk logits). Finished/inactive lanes
    are masked: null block table + length 0 sinks their write into the null
    block and keeps the step fully batched.
    """
    token, pool, lengths, remaining = carry
    active = remaining > 0
    eff_tables = jnp.where(active[:, None], block_tables, 0)
    eff_lengths = jnp.where(active, lengths, 0)
    logits, extra, pool = run(token, eff_tables, eff_lengths, pool)
    if sampler is None or sampler.temperature <= 0.0:
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    else:
        # deferred: keeps core free of a top-level serving dependency
        from repro.serving.sampler import sample
        nxt = sample(logits[:, -1, :], key, sampler)
    nxt = jnp.where(active, nxt, token[:, 0])
    new_remaining = jnp.where(active, remaining - 1, 0)
    if eos_id is not None:
        new_remaining = jnp.where(active & (nxt == eos_id), 0,
                                  new_remaining)
    new_lengths = lengths + active.astype(jnp.int32)
    return ((nxt[:, None], pool, new_lengths, new_remaining),
            (nxt, active), extra)


@partial(jax.jit,
         static_argnames=("decode_step", "n_steps", "sampler", "eos_id"),
         donate_argnums=(2,))
def _paged_window(params, token, pool, block_tables, lengths, remaining,
                  step_keys, *, decode_step, n_steps: int, sampler, eos_id):
    def run(token, eff_tables, eff_lengths, pool):
        logits, pool = decode_step(params, token, pool,
                                   block_tables=eff_tables,
                                   lengths=eff_lengths)
        return logits, None, pool

    def step(carry, key):
        carry, out, _ = _masked_step(run, carry, key,
                                     block_tables=block_tables,
                                     sampler=sampler, eos_id=eos_id)
        return carry, out

    (token, pool, lengths, remaining), (toks, valid) = jax.lax.scan(
        step, (token, pool, lengths, remaining), step_keys, length=n_steps)
    return toks.T, valid.T, pool, lengths, remaining


@partial(jax.jit,
         static_argnames=("decode_step", "mixed_step", "n_steps", "sampler",
                          "eos_id"),
         donate_argnums=(2,))
def _paged_mixed_window(params, token, pool, block_tables, lengths, remaining,
                        step_keys, prefill_tokens, prefill_table,
                        prefill_start, *, decode_step, mixed_step,
                        n_steps: int, sampler, eos_id):
    """Window carrying an in-flight prefill chunk: step 0 is the fused
    ``mixed_step`` (decode lanes ⊕ prefill chunk, one pool write), the
    remaining ``n_steps - 1`` steps are pure batched decode — all ONE
    dispatch, so admission costs zero extra host round-trips."""
    def run_mixed(token, eff_tables, eff_lengths, pool):
        logits, pre_logits, pool = mixed_step(
            params, token, prefill_tokens, pool,
            decode_tables=eff_tables, decode_lengths=eff_lengths,
            prefill_table=prefill_table, prefill_start=prefill_start)
        return logits, pre_logits, pool

    def run_decode(token, eff_tables, eff_lengths, pool):
        logits, pool = decode_step(params, token, pool,
                                   block_tables=eff_tables,
                                   lengths=eff_lengths)
        return logits, None, pool

    carry = (token, pool, lengths, remaining)
    carry, (tok0, act0), pre_logits = _masked_step(
        run_mixed, carry, step_keys[0], block_tables=block_tables,
        sampler=sampler, eos_id=eos_id)

    def step(carry, key):
        carry, out, _ = _masked_step(run_decode, carry, key,
                                     block_tables=block_tables,
                                     sampler=sampler, eos_id=eos_id)
        return carry, out

    (token, pool, lengths, remaining), (toks, valid) = jax.lax.scan(
        step, carry, step_keys[1:], length=n_steps - 1)
    toks = jnp.concatenate([tok0[None], toks], axis=0)
    valid = jnp.concatenate([act0[None], valid], axis=0)
    return toks.T, valid.T, pre_logits, pool, lengths, remaining


def paged_decode_window(model, params, last_token, pool, block_tables,
                        lengths, remaining, rng, n_steps: int, *,
                        sampler=None, eos_id=None, prefill_tokens=None,
                        prefill_table=None, prefill_start=0,
                        mixed_step_fn=None, decode_step_fn=None,
                        tracer=None):
    """Fused-window paged decode: ONE dispatch for ``n_steps`` batched steps.

    last_token: [W, 1] each lane's most recent token; block_tables: [W, NBmax]
    (pre-grown on the host to cover the whole window's writes); lengths: [W]
    write positions; remaining: [W] per-lane steps still to emit (0 = lane
    inactive for the whole window). Greedy when ``sampler`` is None or
    temperature 0; otherwise one fold of ``rng`` per step.

    Returns (tokens [W, n_steps], valid [W, n_steps] bool, pool,
    final lengths [W], final remaining [W]) — the host reconciles per-lane
    outputs/lengths/blocks from the valid mask after the window.

    With ``prefill_tokens`` ([1, C]) + ``prefill_table`` ([1, NBmax]) the
    window additionally carries one prefill chunk of an admitting request
    (stage-parallel mixed batching): the fused graph runs the chunk
    concurrently with the window's first decode step, and the return gains
    the chunk's last-token logits as a third element —
    (tokens, valid, prefill_logits, pool, lengths, remaining).
    ``mixed_step_fn`` / ``decode_step_fn`` must be STABLE callables (cached
    by the caller, e.g. ``partial(model.mixed_step, hetero_ctx=ctx)`` or a
    layout object's shard_map-wrapped step) so jit caching holds across
    windows; they default to the model's own step functions. The override is
    how tensor-parallel serving threads its sharded step into the fused
    window: the shard_map body simply becomes the scanned step.

    ``tracer`` (duck-typed — core never imports serving) wraps the fused
    dispatch in a ``fused_window`` span so the trace shows the window
    boundary — the one host round-trip — nested inside the scheduler's
    dispatch span. The span surrounds the HOST-side jit call only; nothing
    traced runs inside the compiled graph.
    """
    keys = jax.random.split(rng, n_steps)
    decode_step = (decode_step_fn if decode_step_fn is not None
                   else model.paged_decode_step)
    mixed = prefill_tokens is not None
    span = (nullcontext() if tracer is None else
            tracer.span("fused_window", track="decode", cat="sync",
                        args={"n_steps": int(n_steps), "mixed": mixed}))
    with span:
        if not mixed:
            return _paged_window(params, last_token, pool, block_tables,
                                 lengths, remaining, keys,
                                 decode_step=decode_step, n_steps=n_steps,
                                 sampler=sampler, eos_id=eos_id)
        return _paged_mixed_window(
            params, last_token, pool, block_tables, lengths, remaining, keys,
            prefill_tokens, prefill_table,
            jnp.asarray(prefill_start, jnp.int32),
            decode_step=decode_step,
            mixed_step=(mixed_step_fn if mixed_step_fn is not None
                        else model.mixed_step),
            n_steps=n_steps, sampler=sampler, eos_id=eos_id)


@lru_cache(maxsize=16)
def _host_loop_jit(decode_step):
    """Per-decode-step-callable jit cache: ``generate_host_loop`` is called
    per request, and re-wrapping decode_step each call would retrace."""
    return jax.jit(decode_step, donate_argnums=(2,))


def generate_host_loop(model, params, first_token, cache, n_steps: int,
                       *, hard_sync: bool = True):
    """Baseline: host dispatches each token step (GPU-2 cost per token)."""
    step = _host_loop_jit(model.decode_step)
    token = first_token
    out = []
    for _ in range(n_steps):
        logits, cache = step(params, token, cache)
        if hard_sync:
            jax.block_until_ready(logits)           # the clFinish analogue
            token = jnp.asarray(jax.device_get(_greedy(logits)))  # host trip
        else:
            token = _greedy(logits)
        out.append(token[:, 0])
    return jnp.stack(out, axis=1), cache


def measure_dispatch_overhead(n: int = 50) -> float:
    """Median microseconds per trivial-dispatch+sync on this backend."""
    x = jnp.zeros((8,), jnp.float32)
    f = jax.jit(lambda a: a + 1)
    f(x).block_until_ready()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()  # repolint: disable=determinism -- measures real per-dispatch wall overhead (the solver's T_sync input); a virtual clock would measure nothing
        f(x).block_until_ready()
        ts.append(time.perf_counter() - t0)  # repolint: disable=determinism -- second half of the same real-wall-time measurement
    ts.sort()
    return ts[len(ts) // 2] * 1e6
