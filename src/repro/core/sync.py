"""Fast synchronization (paper §4.3), TPU-native.

The paper's problem: host-driver sync (clFinish ~400us) between every
GPU/NPU kernel dwarfs decode kernels. The JAX analogue is the host-stepped
decode loop: one dispatch + block_until_ready + host round-trip per token.
The fix is the same idea as the paper's shared-buffer flag polling — keep
the whole loop on device:

  * ``generate_on_device``  — a single jitted ``lax.scan`` over decode steps
    with donated cache buffers: zero host round-trips ("fast sync").
  * ``generate_host_loop``  — the baseline: one jitted decode_step per token,
    host-synced each step (the clFinish analogue). ``hard_sync=True`` adds a
    device->host token fetch per step (the worst case the paper measures).

``measure_dispatch_overhead`` quantifies the per-dispatch cost on the current
backend — the number the solver uses as T_sync in 'host' mode.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp


def _greedy(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


@partial(jax.jit, static_argnames=("decode_step", "n_steps"), donate_argnums=(2,))
def _device_loop(params, first_token, cache, *, decode_step, n_steps: int):
    def step(carry, _):
        token, cache = carry
        logits, cache = decode_step(params, token, cache)
        nxt = _greedy(logits)
        return (nxt, cache), nxt[:, 0]

    (_, cache), toks = jax.lax.scan(step, (first_token, cache), None,
                                    length=n_steps)
    return toks.T, cache        # [B, n_steps]


def generate_on_device(model, params, first_token, cache, n_steps: int):
    """Fast-sync path: the entire decode loop is one device program."""
    return _device_loop(params, first_token, cache,
                        decode_step=model.decode_step, n_steps=n_steps)


def generate_host_loop(model, params, first_token, cache, n_steps: int,
                       *, hard_sync: bool = True):
    """Baseline: host dispatches each token step (GPU-2 cost per token)."""
    step = jax.jit(model.decode_step, donate_argnums=(2,))
    token = first_token
    out = []
    for _ in range(n_steps):
        logits, cache = step(params, token, cache)
        if hard_sync:
            jax.block_until_ready(logits)           # the clFinish analogue
            token = jnp.asarray(jax.device_get(_greedy(logits)))  # host trip
        else:
            token = _greedy(logits)
        out.append(token[:, 0])
    return jnp.stack(out, axis=1), cache


def measure_dispatch_overhead(n: int = 50) -> float:
    """Median microseconds per trivial-dispatch+sync on this backend."""
    x = jnp.zeros((8,), jnp.float32)
    f = jax.jit(lambda a: a + 1)
    f(x).block_until_ready()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
