"""HeteroInfer inference engine (paper §4.4 "Inference Engine", Fig 11).

Offline: profiler -> solver -> PartitionPlan (graphs "generated in advance").
Online: per request, pick the prefill strategy for the ACTUAL sequence length
and run decode with fast synchronization.

Engine modes (the paper's eval arms):
  'xla'            — flexible-path only            (= MNN/MLC GPU-only)
  'mxu'            — aligned-path only, pad to buckets (= llm.npu/PI-2 NPU-only)
  'hetero-layer'   — per-op affinity (§4.1)
  'hetero-tensor'  — solver-driven tensor partitioning (§4.2)

Prefill strategies for dynamic lengths (paper §5.3.2 / Fig 14):
  'online-prepare' — (re)trace+compile at the exact length each time
  'padding'        — pad every matmul's token dim to the next bucket
  'pipe'           — sequential standard-bucket chunked prefill (NPU-pipe)
  'hetero'         — standard-bucket chunks + ragged remainder chunk
                     (multi-tensor activation partitioning, Fig 9)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import build_model

from .partition import HeteroCtx
from .profiler import LatencyTable, STANDARD_BUCKETS, profile_analytic
from .solver import PartitionSolver, PartitionPlan
from .sync import fence, generate_host_loop, generate_on_device


def build_plan(cfg, *, sync_mode: str = "fast",
               table: Optional[LatencyTable] = None, mixed_pairs=(),
               verify_ks=(), extra_ms=(),
               weight_quant: Optional[str] = None) -> tuple[LatencyTable,
                                                            PartitionPlan]:
    """Offline phase (paper Fig 11 left half): profile the model's weight
    shapes, then solve the per-(site, M) partitioning decisions. Shared by
    the single-stream engine and the paged serving scheduler so both run
    the SAME solver-planned execution. ``mixed_pairs``: (prefill chunk,
    decode width) pairs the mixed-batch scheduler will fuse — solved into
    ``plan.mixed_decisions`` (strategy MIXED). ``verify_ks``: (k, lanes)
    speculative-verification shapes the spec decoder will dispatch —
    solved into ``plan.verify_decisions`` (the VERIFY site class).
    ``extra_ms``: extra token counts added to the solve grid — the
    prefix-cache scheduler's suffix-chunk lengths, so warm-path chunks get
    first-class solved decisions. ``weight_quant`` (None | 'int8' |
    'w4a16'): profile and solve against the quantized weight-stream bytes —
    memory-bound decode shapes re-plan when the weight HBM traffic halves
    (or quarters), so a quantized deployment gets its own plan."""
    table = table or profile_analytic(cfg, weight_quant=weight_quant)
    solver = PartitionSolver(table, sync_mode=sync_mode,
                             weight_quant=weight_quant)
    return table, solver.solve(cfg, mixed_pairs=mixed_pairs,
                               verify_ks=verify_ks, extra_ms=extra_ms)


def build_hetero_ctx(cfg, mode: str, *, sync_mode: str = "fast",
                     interpret: bool = True, mixed_pairs=(),
                     verify_ks=(), extra_ms=(),
                     weight_quant: Optional[str] = None) -> HeteroCtx:
    """Profile + solve + wrap in the HeteroCtx that models thread through
    every matmul site (including the LM head)."""
    _, plan = build_plan(cfg, sync_mode=sync_mode, mixed_pairs=mixed_pairs,
                         verify_ks=verify_ks, extra_ms=extra_ms,
                         weight_quant=weight_quant)
    return HeteroCtx(mode=mode, plan=plan, interpret=interpret)


def dispatch_prediction(plan, cfg, *, m=None, steps: int = 1,
                        mixed=None, verify=None):
    """Decision tags + predicted duration for ONE scheduler dispatch.

    Returns ``(tags, total_us)`` where tags is a tuple of
    ``(site, M, strategy, t_us, count)`` — one per partitionable site —
    and ``count`` folds in how many times that site's matmul runs inside
    the dispatch: ``steps`` forward passes, each hitting every
    non-``head`` site ``cfg.n_layers`` times and ``head`` once (mirroring
    :meth:`InferenceEngine.predicted_prefill_us`). Exactly one shape
    selector applies: ``m`` (plain M-token dispatch, nearest-grid-M
    lookup — decode widths and off-bucket chunks resolve the same way
    HeteroCtx picks kernels), ``mixed=(m_prefill, m_decode)`` (fused
    stage-parallel step) or ``verify=(k, lanes)`` (spec verification).
    The serving tracer attaches these tags to each dispatch span and the
    drift aggregator scores them against measured durations. ``plan=None``
    (no engine mode, no solver) yields ``((), 0.0)`` — untagged spans."""
    if plan is None:
        return (), 0.0
    sites = sorted({s for (s, _) in plan.decisions})
    tags, total = [], 0.0
    for site in sites:
        if verify is not None:
            k, lanes = verify
            dec = plan.verify_decision(site, k, lanes) \
                or plan.lookup(site, lanes * (k + 1))
        elif mixed is not None:
            mp, md = mixed
            dec = plan.mixed_decision(site, mp, md) \
                or plan.lookup(site, mp + md)
        else:
            dec = plan.lookup(site, 1 if m is None else m)
        if dec is None:
            continue
        count = steps * (1 if site == "head" else cfg.n_layers)
        tags.append((site, dec.M, dec.strategy, dec.t_us, count))
        total += dec.t_us * count
    return tuple(tags), total


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    compile_s: float = 0.0
    n_compiles: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0

    def tokens_per_s(self) -> dict:
        return {
            "prefill_tok_s": self.prefill_tokens / self.prefill_s
            if self.prefill_s else 0.0,
            "decode_tok_s": self.decode_tokens / self.decode_s
            if self.decode_s else 0.0,
        }


class InferenceEngine:
    def __init__(self, cfg, params=None, *, mode: str = "hetero-tensor",
                 prefill_strategy: str = "hetero", fast_sync: bool = True,
                 table: Optional[LatencyTable] = None,
                 plan: Optional[PartitionPlan] = None,
                 buckets: tuple = STANDARD_BUCKETS,
                 max_len: int = 2048, interpret: bool = True,
                 use_kernels: bool = True, rng=None, clock=None):
        # EngineStats timing reads the injected clock (serving/telemetry
        # Clock protocol) — MonotonicClock by default, FakeClock in tests
        # keeps tier-1 free of wall-clock reads.
        if clock is None:
            from repro.serving.telemetry import MonotonicClock
            clock = MonotonicClock()
        self.clock = clock
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        self.mode = mode
        self.prefill_strategy = prefill_strategy
        self.fast_sync = fast_sync
        self.buckets = tuple(sorted(buckets))
        self.max_len = max_len
        if plan is None:
            self.table, self.plan = build_plan(
                cfg, sync_mode="fast" if fast_sync else "host", table=table)
        else:
            self.table, self.plan = table or profile_analytic(cfg), plan
        # use_kernels: route MXU-path matmuls through the Pallas kernel
        # (interpret mode on CPU — functional; CPU wall-times of the MXU
        # path are NOT representative of silicon, the analytic arms are).
        self.ctx = HeteroCtx(mode=mode, plan=self.plan,
                             interpret=interpret) if use_kernels else None
        self.stats = EngineStats()
        self._prefill_cache: dict = {}

    # ------------------------------------------------------------- helpers --
    def _jit_prefill(self, chunk_len: int):
        """One compiled graph per chunk length ('graphs generated in
        advance'); a NEW length costs a trace+compile — the cost
        Online-prepare pays per request and bucketing amortizes."""
        key = ("prefill", chunk_len)
        new = key not in self._prefill_cache
        if new:
            self._prefill_cache[key] = jax.jit(
                partial(self.model.prefill, hetero_ctx=self.ctx),
                donate_argnums=(2,))
            self.stats.n_compiles += 1
        return self._prefill_cache[key], new

    def _bucket_chunks(self, S: int) -> list[tuple[int, int]]:
        """Split S into (chunk_graph_size, true_tokens) pieces."""
        if self.prefill_strategy in ("online-prepare", "padding"):
            return [(S, S)]     # padding happens inside matmuls (PAD decisions)
        if self.prefill_strategy == "pipe":
            # NPU-pipe: standard-size chunks over the first S-1 tokens (the
            # tail padded to the smallest bucket), then an EXACT 1-token
            # chunk so last-token logits come from the true final position.
            chunks, rem = [], S - 1
            for b in sorted(self.buckets, reverse=True):
                while rem >= b:
                    chunks.append((b, b))
                    rem -= b
            if rem:
                chunks.append((min(self.buckets), rem))       # padded tail
            chunks.append((1, 1))
            return chunks
        chunks, rem = [], S
        for b in sorted(self.buckets, reverse=True):
            while rem >= b:
                chunks.append((b, b))
                rem -= b
        if rem:
            chunks.append((rem, rem))   # hetero: ragged remainder (XLA path)
        return chunks

    # -------------------------------------------------------------- public --
    def generate(self, prompt: jax.Array, max_new_tokens: int = 32,
                 greedy: bool = True) -> jax.Array:
        """prompt: [B, S] int32. Returns [B, max_new_tokens]."""
        B, S = prompt.shape
        # pipe's padded tail may write up to min(buckets)-1 slots past S;
        # without headroom the dynamic_update_slice would CLAMP and corrupt
        # earlier cache slots.
        pad_headroom = (min(self.buckets) if self.prefill_strategy == "pipe"
                        else 0)
        total = S + max_new_tokens + pad_headroom
        cache = self.model.init_cache(
            batch=B, max_len=total,
            dtype=jnp.dtype(self.cfg.compute_dtype))

        t0 = self.clock.now()
        chunks = self._bucket_chunks(S)
        idx = 0
        logits = None
        for c, take in chunks:
            piece = prompt[:, idx: idx + take]
            if take < c:                # pipe-mode padded tail
                piece = jnp.pad(piece, ((0, 0), (0, c - take)))
            fn, new = self._jit_prefill(c)
            tc = self.clock.now()
            logits, cache = fn(self.params, piece, cache, start_index=idx)
            if new:                     # first call pays trace+compile
                fence(logits)
                self.stats.compile_s += self.clock.now() - tc
            idx += take
        cache = {**cache, "index": jnp.asarray(S, jnp.int32)}
        fence(logits)
        self.stats.prefill_s += self.clock.now() - t0
        self.stats.prefill_tokens += B * S

        first = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        t0 = self.clock.now()
        n_more = max_new_tokens - 1
        if n_more > 0:
            gen = generate_on_device if self.fast_sync else generate_host_loop
            toks, cache = gen(self.model, self.params, first, cache, n_more)
            out = jnp.concatenate([first, toks], axis=1)
        else:
            out = first
        fence(out)
        self.stats.decode_s += self.clock.now() - t0
        self.stats.decode_tokens += B * max_new_tokens
        return out

    # --------------------------------------------------- analytic latencies --
    def predicted_prefill_us(self, S: int) -> float:
        """Solver-predicted prefill matmul latency for length S (per layer
        set), used by the paper-faithful latency benchmarks."""
        total = 0.0
        for site in self.table.sites:
            if site == "head":
                continue
            dec = PartitionSolver(self.table,
                                  sync_mode="fast" if self.fast_sync else "host"
                                  ).solve_site(site, max(S, 1))
            total += dec.t_us
        return total * self.cfg.n_layers
