"""Partition execution: the HeteroCtx that models thread through every matmul.

``HeteroCtx.matmul(x, w, name=site)`` consults the PartitionPlan (or the
engine mode) and executes the chosen strategy:

  xla_only  : one flexible-path matmul
  mxu_only  : aligned Pallas MXU-path matmul (pad M/K/N to 128 = the NPU's
              internal stage padding); order-exchange applied when profitable
              (NPU-2: y = x@w  ->  y = (w^T @ x^T)^T when x is the smaller,
              better-stationary operand)
  pad       : mxu_only with M padded up to the decision's bucket
  weight    : weight-centric split — MXU path computes the 128-aligned major
              column block, XLA path the remainder columns; the two matmuls
              are data-independent so XLA schedules them concurrently (the
              GPU||NPU analogue)
  act       : activation-centric split — first ``m_bucket`` tokens on the MXU
              path, ragged tail on the XLA path
  hybrid    : act bucketing + weight split of the bucketed part

Everything happens at trace time (static shapes), so a jitted program bakes
in the plan — the paper's 'graphs generated in advance by the solver'.

Speculative-decoding verification dispatches use a context view from
``for_verify(k, lanes)``: same strategies, but sites resolve through the
plan's VERIFY decisions (solver.py ``solve_verify``) instead of the generic
nearest-M grid.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hetero_matmul.ops import (mxu_matmul, mxu_q4_matmul,
                                             mxu_quant_matmul)

from .characteristics import V5E, mxu_matmul_time_us
from .solver import Decision, PartitionPlan

ALIGN = 128


def _pad_to(x, mult, axis):
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - r)
    return jnp.pad(x, pads)


@jax.tree_util.register_pytree_node_class
class QuantWeight:
    """A quantized weight that flows anywhere an fp weight array does.

    Per-output-channel symmetric quantization in one of two storage formats
    (the paper's deployment stances):

      * ``int8``  — ``wq`` int8 ``[..., K, N]``, ``scale`` f32 ``[..., N]``
      * ``w4a16`` — ``wq`` int8 ``[..., ceil(K/2), N]`` with two int4 codes
        packed per byte along K (rows 2r, 2r+1 -> lo, hi nibbles), same
        per-column scale

    Registered as a pytree node (arrays are children, ``fmt``/``k`` are
    static aux data) so stacked per-layer quantized weights thread through
    ``lax.scan``/``jit`` exactly like fp arrays: scan slices the leading
    layer axis of ``wq`` and ``scale`` and the model sees a per-layer
    ``QuantWeight``. ``k`` is the LOGICAL contraction dim — the int4 packer
    zero-pads odd K, so storage and logical K can differ.
    """

    def __init__(self, wq, scale, fmt: str, k: int):
        assert fmt in ("int8", "w4a16"), fmt
        self.wq = wq
        self.scale = scale
        self.fmt = fmt
        self.k = int(k)

    def tree_flatten(self):
        return (self.wq, self.scale), (self.fmt, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        """Logical [..., K, N] shape (what the fp weight would report)."""
        return (*self.wq.shape[:-2], self.k, self.wq.shape[-1])

    @property
    def n(self) -> int:
        return self.wq.shape[-1]

    def dequant(self, dtype=jnp.float32):
        """Dequantize-then-cast reference expansion (the XLA-path execution
        and the conformance oracle)."""
        if self.fmt == "int8":
            q = self.wq.astype(jnp.float32)
        else:
            lo = jnp.left_shift(self.wq, 4) >> 4     # sign-extended low nibble
            hi = self.wq >> 4                        # arithmetic high nibble
            k2, n = self.wq.shape[-2], self.wq.shape[-1]
            q = jnp.stack([lo, hi], axis=-2).reshape(
                *self.wq.shape[:-2], 2 * k2, n)[..., :self.k, :]
            q = q.astype(jnp.float32)
        return (q * self.scale[..., None, :]).astype(dtype)

    def slice_n(self, a: int, b: int) -> "QuantWeight":
        """Column (output-channel) slice — packing is along K, so any N
        split point is representable; this is what makes the solver's
        weight/hybrid strategies legal on quantized sites."""
        return QuantWeight(self.wq[..., :, a:b], self.scale[..., a:b],
                           self.fmt, self.k)


def _weight_cols(w, a: int, b: int):
    return w.slice_n(a, b) if isinstance(w, QuantWeight) else w[:, a:b]


def matmul_any(x, w, name: Optional[str] = None):
    """Plan-free matmul over fp or quantized weights — the model code's
    fallback when no HeteroCtx is threaded (training, references)."""
    if isinstance(w, QuantWeight):
        return x @ w.dequant(x.dtype)
    return x @ w


@dataclass
class HeteroCtx:
    """mode: 'xla' | 'mxu' | 'hetero-layer' | 'hetero-tensor'."""
    mode: str = "hetero-tensor"
    plan: Optional[PartitionPlan] = None
    interpret: bool = True
    order_exchange: bool = True
    layer_mxu_threshold: int = 128       # hetero-layer: M >= this -> MXU path
    stationary: str = "output"
    # VERIFY site class (speculative decoding): when set to (k, lanes), every
    # matmul consults plan.verify_decisions first — the solver's plan for the
    # M = lanes*(k+1) verification dispatch, not the generic nearest-M grid
    verify_key: Optional[tuple] = None

    def for_verify(self, k: int, lanes: int = 1) -> "HeteroCtx":
        """A view of this context for verification dispatches: same plan,
        same mode, but matmul sites resolve through the VERIFY decisions
        solved for (k, lanes). Callers bake the returned ctx into the jitted
        ``paged_verify`` graph (trace-time, like every other decision)."""
        return replace(self, verify_key=(k, lanes))

    # ---------------------------------------------------------- primitives --
    def _mxu(self, x2, w):
        """Aligned MXU-path matmul with internal stage padding + NPU-2
        order-exchange. Quantized weights dispatch the in-VMEM-dequant
        kernels (``mxu_quant_matmul`` / ``mxu_q4_matmul``); order-exchange
        is fp-only (a packed weight can't become the streamed operand)."""
        if isinstance(w, QuantWeight):
            return self._mxu_quant(x2, w)
        M, K = x2.shape
        N = w.shape[1]
        use_exchange = (self.order_exchange and
                        mxu_matmul_time_us(N, K, M) < mxu_matmul_time_us(M, K, N))
        xp = _pad_to(_pad_to(x2, ALIGN, 0), ALIGN, 1)
        wp = _pad_to(_pad_to(w.astype(x2.dtype), ALIGN, 0), ALIGN, 1)
        if use_exchange:
            y = mxu_matmul(wp.T, xp.T, interpret=self.interpret,
                           stationary=self.stationary).T
        else:
            y = mxu_matmul(xp, wp, interpret=self.interpret,
                           stationary=self.stationary)
        return y[:M, :N]

    def _mxu_quant(self, x2, w: QuantWeight):
        """Stage padding for the quantized MXU kernels: codes pad with 0
        (dequants to exactly 0 against any scale), scales pad with 0 — the
        padded columns are sliced off. x pads along K with zeros, so the
        code rows beyond the logical K contribute nothing either way."""
        M = x2.shape[0]
        N = w.n
        xp = _pad_to(_pad_to(x2, ALIGN, 0), ALIGN, 1)
        sp = _pad_to(w.scale, ALIGN, -1)
        if w.fmt == "int8":
            wqp = _pad_to(_pad_to(w.wq, ALIGN, 0), ALIGN, 1)
            y = mxu_quant_matmul(xp, wqp, sp, interpret=self.interpret)
        else:
            # packed rows count ceil(K/2) pads to Kp//2 (Kp = xp's padded K)
            wqp = _pad_to(_pad_to(w.wq, ALIGN // 2, 0), ALIGN, 1)
            y = mxu_q4_matmul(xp, wqp, sp, interpret=self.interpret)
        return y[:M, :N]

    def _xla(self, x2, w):
        if isinstance(w, QuantWeight):
            return x2 @ w.dequant(x2.dtype)
        return x2 @ w.astype(x2.dtype)

    # ------------------------------------------------------------ dispatch --
    def matmul(self, x, w, name: Optional[str] = None):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        M, N = x2.shape[0], w.shape[1]

        if self.mode == "xla":
            y = self._xla(x2, w)
        elif self.mode == "mxu":
            y = self._mxu(x2, w)
        elif self.mode == "hetero-layer":
            y = self._mxu(x2, w) if M >= self.layer_mxu_threshold else \
                self._xla(x2, w)
        else:
            y = self._tensor_level(x2, w, name, M, N)
        return y.reshape(*lead, N)

    def _tensor_level(self, x2, w, name, M, N):
        dec = None
        if self.plan is not None and name is not None:
            if self.verify_key is not None:
                dec = self.plan.verify_decision(name, *self.verify_key)
            if dec is None:
                dec = self.plan.decision(name, M)
            if dec is None:       # nearest-M fallback (solver probes a grid)
                ms = sorted({m for (s, m) in self.plan.decisions if s == name})
                if ms:
                    nearest = min(ms, key=lambda m: abs(m - M))
                    dec = self.plan.decision(name, nearest)
        if dec is None:
            return self._mxu(x2, w) if M >= ALIGN else self._xla(x2, w)
        return self.execute(dec, x2, w)

    def execute(self, dec: Decision, x2, w):
        M, N = x2.shape[0], w.shape[1]
        s = dec.strategy
        if s == "xla_only":
            return self._xla(x2, w)
        if s in ("mxu_only", "pad"):
            return self._mxu(x2, w)     # _mxu pads M internally (stage padding)
        if s == "weight":
            n = min(dec.n_split, N - 1)
            y1 = self._mxu(x2, _weight_cols(w, 0, n))
            y2 = self._xla(x2, _weight_cols(w, n, N))
            return jnp.concatenate([y1, y2], axis=-1)
        if s == "act":
            b = min(dec.m_bucket, M - 1) if dec.m_bucket < M else M - ALIGN
            b = max(b, 1)
            y1 = self._mxu(x2[:b], w)
            y2 = self._xla(x2[b:], w)
            return jnp.concatenate([y1, y2], axis=0)
        if s == "hybrid":
            b = min(dec.m_bucket, M - 1)
            b = max(b, 1)
            n = min(dec.n_split, N - 1)
            y1a = self._mxu(x2[:b], _weight_cols(w, 0, n))
            y1b = self._xla(x2[:b], _weight_cols(w, n, N))
            y2 = self._xla(x2[b:], w)
            return jnp.concatenate(
                [jnp.concatenate([y1a, y1b], axis=-1), y2], axis=0)
        raise ValueError(f"unknown strategy {s}")
