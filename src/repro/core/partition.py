"""Partition execution: the HeteroCtx that models thread through every matmul.

``HeteroCtx.matmul(x, w, name=site)`` consults the PartitionPlan (or the
engine mode) and executes the chosen strategy:

  xla_only  : one flexible-path matmul
  mxu_only  : aligned Pallas MXU-path matmul (pad M/K/N to 128 = the NPU's
              internal stage padding); order-exchange applied when profitable
              (NPU-2: y = x@w  ->  y = (w^T @ x^T)^T when x is the smaller,
              better-stationary operand)
  pad       : mxu_only with M padded up to the decision's bucket
  weight    : weight-centric split — MXU path computes the 128-aligned major
              column block, XLA path the remainder columns; the two matmuls
              are data-independent so XLA schedules them concurrently (the
              GPU||NPU analogue)
  act       : activation-centric split — first ``m_bucket`` tokens on the MXU
              path, ragged tail on the XLA path
  hybrid    : act bucketing + weight split of the bucketed part

Everything happens at trace time (static shapes), so a jitted program bakes
in the plan — the paper's 'graphs generated in advance by the solver'.

Speculative-decoding verification dispatches use a context view from
``for_verify(k, lanes)``: same strategies, but sites resolve through the
plan's VERIFY decisions (solver.py ``solve_verify``) instead of the generic
nearest-M grid.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hetero_matmul.ops import mxu_matmul

from .characteristics import V5E, mxu_matmul_time_us
from .solver import Decision, PartitionPlan

ALIGN = 128


def _pad_to(x, mult, axis):
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - r)
    return jnp.pad(x, pads)


@dataclass
class HeteroCtx:
    """mode: 'xla' | 'mxu' | 'hetero-layer' | 'hetero-tensor'."""
    mode: str = "hetero-tensor"
    plan: Optional[PartitionPlan] = None
    interpret: bool = True
    order_exchange: bool = True
    layer_mxu_threshold: int = 128       # hetero-layer: M >= this -> MXU path
    stationary: str = "output"
    # VERIFY site class (speculative decoding): when set to (k, lanes), every
    # matmul consults plan.verify_decisions first — the solver's plan for the
    # M = lanes*(k+1) verification dispatch, not the generic nearest-M grid
    verify_key: Optional[tuple] = None

    def for_verify(self, k: int, lanes: int = 1) -> "HeteroCtx":
        """A view of this context for verification dispatches: same plan,
        same mode, but matmul sites resolve through the VERIFY decisions
        solved for (k, lanes). Callers bake the returned ctx into the jitted
        ``paged_verify`` graph (trace-time, like every other decision)."""
        return replace(self, verify_key=(k, lanes))

    # ---------------------------------------------------------- primitives --
    def _mxu(self, x2, w):
        """Aligned MXU-path matmul with internal stage padding + NPU-2
        order-exchange."""
        M, K = x2.shape
        N = w.shape[1]
        use_exchange = (self.order_exchange and
                        mxu_matmul_time_us(N, K, M) < mxu_matmul_time_us(M, K, N))
        xp = _pad_to(_pad_to(x2, ALIGN, 0), ALIGN, 1)
        wp = _pad_to(_pad_to(w.astype(x2.dtype), ALIGN, 0), ALIGN, 1)
        if use_exchange:
            y = mxu_matmul(wp.T, xp.T, interpret=self.interpret,
                           stationary=self.stationary).T
        else:
            y = mxu_matmul(xp, wp, interpret=self.interpret,
                           stationary=self.stationary)
        return y[:M, :N]

    def _xla(self, x2, w):
        return x2 @ w.astype(x2.dtype)

    # ------------------------------------------------------------ dispatch --
    def matmul(self, x, w, name: Optional[str] = None):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        M, N = x2.shape[0], w.shape[1]

        if self.mode == "xla":
            y = self._xla(x2, w)
        elif self.mode == "mxu":
            y = self._mxu(x2, w)
        elif self.mode == "hetero-layer":
            y = self._mxu(x2, w) if M >= self.layer_mxu_threshold else \
                self._xla(x2, w)
        else:
            y = self._tensor_level(x2, w, name, M, N)
        return y.reshape(*lead, N)

    def _tensor_level(self, x2, w, name, M, N):
        dec = None
        if self.plan is not None and name is not None:
            if self.verify_key is not None:
                dec = self.plan.verify_decision(name, *self.verify_key)
            if dec is None:
                dec = self.plan.decision(name, M)
            if dec is None:       # nearest-M fallback (solver probes a grid)
                ms = sorted({m for (s, m) in self.plan.decisions if s == name})
                if ms:
                    nearest = min(ms, key=lambda m: abs(m - M))
                    dec = self.plan.decision(name, nearest)
        if dec is None:
            return self._mxu(x2, w) if M >= ALIGN else self._xla(x2, w)
        return self.execute(dec, x2, w)

    def execute(self, dec: Decision, x2, w):
        M, N = x2.shape[0], w.shape[1]
        s = dec.strategy
        if s == "xla_only":
            return self._xla(x2, w)
        if s in ("mxu_only", "pad"):
            return self._mxu(x2, w)     # _mxu pads M internally (stage padding)
        if s == "weight":
            n = min(dec.n_split, N - 1)
            y1 = self._mxu(x2, w[:, :n])
            y2 = self._xla(x2, w[:, n:])
            return jnp.concatenate([y1, y2], axis=-1)
        if s == "act":
            b = min(dec.m_bucket, M - 1) if dec.m_bucket < M else M - ALIGN
            b = max(b, 1)
            y1 = self._mxu(x2[:b], w)
            y2 = self._xla(x2[b:], w)
            return jnp.concatenate([y1, y2], axis=0)
        if s == "hybrid":
            b = min(dec.m_bucket, M - 1)
            b = max(b, 1)
            n = min(dec.n_split, N - 1)
            y1a = self._mxu(x2[:b], w[:, :n])
            y1b = self._xla(x2[:b], w[:, n:])
            y2 = self._xla(x2[b:], w)
            return jnp.concatenate(
                [jnp.concatenate([y1a, y1b], axis=-1), y2], axis=0)
        raise ValueError(f"unknown strategy {s}")
