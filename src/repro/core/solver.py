"""Tensor partitioning solver (paper §4.4).

For every matmul site and token count M, enumerate the feasible strategies
and minimize

    T_total = min( max(T_xla^p1, T_mxu^p2) + T_sync + T_copy,
                   T_xla^all,
                   T_mxu^all + T_sync + T_copy )        s.t. p1 + p2 = all

Strategies (paper §4.2):
  * XLA_ONLY / MXU_ONLY        — no partition (Table 3 rows 3/4)
  * WEIGHT   — weight-centric: split N at a 128-aligned ratio; both paths run
               the full token set on complementary output columns (Fig 7)
  * ACT      — activation-centric: tokens split into the largest standard
               bucket on the MXU path + dynamic remainder on the XLA path
               (Fig 9) — this is also how dynamic shapes avoid recompiles
  * HYBRID   — ACT bucketing on tokens + WEIGHT split of the bucketed part
  * PAD      — pad M up to the next bucket, MXU only (the Padding baseline)
  * MIXED    — stage-parallel serving pair (``solve_mixed``): a decode
               micro-batch on the flexible path running CONCURRENTLY with an
               aligned prefill chunk on the MXU path at the same weight site,
               sharing the dual-stream bandwidth pool (Memory-1). This is the
               cost model behind the scheduler's mixed batching
               (serving/scheduler.py::PagedBatcher(mixed_batch=True)).

Site classes: the plain decisions cover prefill/decode token counts; the
VERIFY class (``solve_verify``) covers speculative-decoding verification
dispatches — ``lanes`` lanes each scoring its pending token plus K drafts,
an M = lanes*(K+1) matmul. Decode proper is stuck at M = lanes on the
memory-bound flexible path; verification is the one decode-side workload
whose M is scheduler-chosen, so it gets its own solved decisions
(``plan.verify_decisions``) and its own gain account (``verify_gain_us``:
one M = lanes*(K+1) dispatch vs K+1 M = lanes dispatches, each paying
T_sync — the paper's §4.3 dispatch tax, removed by batching tokens instead
of fusing windows).

The solver additionally picks the distributed KV layout for decode
("kv head-parallel" vs "kv sequence-parallel" split-KV) from the collective
model — the mesh-level expression of the same partitioning decision.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, asdict, field
from pathlib import Path
from typing import Optional

from .characteristics import (WEIGHT_BYTES_PER_EL, TPUSpec, V5E,
                              combine_dual, combine_single,
                              mxu_matmul_parts, sync_cost_us,
                              xla_matmul_parts)
from .profiler import LatencyTable, STANDARD_BUCKETS, model_weight_shapes


ALIGN = 128


@dataclass(frozen=True)
class Decision:
    site: str
    M: int
    strategy: str                  # xla_only | mxu_only | weight | act | hybrid | pad
    t_us: float
    # weight-centric: n_mxu columns on the MXU path (128-aligned), rest XLA
    n_split: int = 0
    # activation-centric: tokens on the MXU path (a standard bucket), rest XLA
    m_bucket: int = 0
    ratio: str = ""                # human-readable "mxu:xla" work ratio

    def describe(self) -> str:
        return (f"{self.site}[M={self.M}] -> {self.strategy} "
                f"(n_split={self.n_split}, m_bucket={self.m_bucket}, "
                f"{self.ratio}) {self.t_us:.1f}us")


@dataclass
class PartitionPlan:
    arch: str
    sync_mode: str
    decisions: dict = field(default_factory=dict)   # (site, M) -> Decision
    kv_mode: Optional[str] = None
    # weight storage dtype the plan was solved for (None | int8 | w4a16):
    # quantized weights shrink the weight HBM stream, so decode-roofline
    # splits re-plan — fp and quantized plans are NOT interchangeable
    weight_quant: Optional[str] = None
    # stage-parallel serving decisions, keyed separately so a fused pair
    # (m_prefill + m_decode) can never collide with a plain-M decision:
    # (site, m_prefill, m_decode) -> Decision(strategy='mixed')
    mixed_decisions: dict = field(default_factory=dict)
    # speculative-decoding VERIFY site class, again its own key space:
    # (site, k, lanes) -> Decision for the M = lanes*(k+1) verification
    verify_decisions: dict = field(default_factory=dict)

    def decision(self, site: str, M: int) -> Optional[Decision]:
        return self.decisions.get((site, M))

    def mixed_decision(self, site: str, m_prefill: int,
                       m_decode: int) -> Optional[Decision]:
        return self.mixed_decisions.get((site, m_prefill, m_decode))

    def verify_decision(self, site: str, k: int,
                        lanes: int = 1) -> Optional[Decision]:
        return self.verify_decisions.get((site, k, lanes))

    def lookup(self, site: str, M: int) -> Optional[Decision]:
        """The decision governing an M-token dispatch at ``site``: exact
        when M is on the solve grid, else the nearest solved M — the SAME
        fallback HeteroCtx uses to pick a kernel at run time, so trace
        decision tags name the decision that actually executed. None when
        the plan has no decisions for the site."""
        dec = self.decisions.get((site, M))
        if dec is not None:
            return dec
        ms = sorted({m for (s, m) in self.decisions if s == site})
        if not ms:
            return None
        return self.decisions[(site, min(ms, key=lambda m: abs(m - M)))]

    def save(self, path):
        Path(path).write_text(json.dumps({
            "arch": self.arch, "sync_mode": self.sync_mode,
            "kv_mode": self.kv_mode, "weight_quant": self.weight_quant,
            "decisions": [asdict(d) for d in self.decisions.values()],
            "mixed_decisions": [[list(k), asdict(d)] for k, d in
                                self.mixed_decisions.items()],
            "verify_decisions": [[list(k), asdict(d)] for k, d in
                                 self.verify_decisions.items()]}))

    @classmethod
    def load(cls, path) -> "PartitionPlan":
        data = json.loads(Path(path).read_text())
        plan = cls(arch=data["arch"], sync_mode=data["sync_mode"],
                   kv_mode=data.get("kv_mode"),
                   weight_quant=data.get("weight_quant"))
        for d in data["decisions"]:
            dec = Decision(**d)
            plan.decisions[(dec.site, dec.M)] = dec
        for k, d in data.get("mixed_decisions", []):
            plan.mixed_decisions[tuple(k)] = Decision(**d)
        for k, d in data.get("verify_decisions", []):
            plan.verify_decisions[tuple(k)] = Decision(**d)
        return plan


class PartitionSolver:
    def __init__(self, table: LatencyTable, spec: TPUSpec = V5E,
                 *, sync_mode: str = "fast",
                 weight_quant: str | None = None):
        self.table = table
        self.spec = spec
        self.sync_mode = sync_mode
        # storage dtype of the weights the plan will execute against; default
        # to whatever the latency table was profiled for so the LUT-backed
        # candidates (xla_only/mxu_only/pad) and the analytic split
        # candidates (weight/act/hybrid/mixed) price the same bytes
        self.weight_quant = weight_quant if weight_quant is not None \
            else getattr(table, "weight_quant", None)
        self._w_bpe = WEIGHT_BYTES_PER_EL[self.weight_quant]

    def _mxu_parts(self, M: int, K: int, N: int) -> tuple[float, int]:
        return mxu_matmul_parts(M, K, N, self.spec,
                                w_bytes_per_el=self._w_bpe)

    def _xla_parts(self, M: int, K: int, N: int) -> tuple[float, int]:
        return xla_matmul_parts(M, K, N, self.spec,
                                w_bytes_per_el=self._w_bpe)

    # ---- per-site-and-M strategy search ------------------------------------
    def solve_site(self, site: str, M: int) -> Decision:
        K, N = self.table.sites[site]
        t_sync = sync_cost_us(self.sync_mode, self.spec)
        t_copy = 0.0            # UMA analogue: both paths share HBM buffers
        lut = self.table.lookup

        cands: list[Decision] = []
        aligned_m = M % ALIGN == 0

        # no-partition candidates
        cands.append(Decision(site, M, "xla_only", lut(site, M, "xla"),
                              ratio="0:1"))
        if aligned_m:
            cands.append(Decision(site, M, "mxu_only",
                                  lut(site, M, "mxu") + t_sync, ratio="1:0"))
        else:
            m_pad = -(-M // ALIGN) * ALIGN
            cands.append(Decision(site, M, "pad",
                                  lut(site, m_pad, "mxu") + t_sync,
                                  m_bucket=m_pad, ratio="1:0(pad)"))

        # weight-centric: N split at a 128-aligned point (Fig 7). Both paths
        # run CONCURRENTLY -> memory time uses the dual-stream pool (Memory-1)
        if N >= 2 * ALIGN:
            Mq = M if aligned_m else -(-M // ALIGN) * ALIGN  # stage padding
            for frac in (i / 8 for i in range(1, 8)):
                n_mxu = int(round(N * frac / ALIGN)) * ALIGN
                if not 0 < n_mxu < N:
                    continue
                t = combine_dual(self._mxu_parts(Mq, K, n_mxu),
                                 self._xla_parts(M, K, N - n_mxu),
                                 self.spec) + t_sync
                cands.append(Decision(site, M, "weight", t, n_split=n_mxu,
                                      ratio=f"{n_mxu}:{N - n_mxu}"))

        # activation-centric: bucket + remainder (Fig 9), concurrent paths
        buckets = [b for b in STANDARD_BUCKETS if b < M]
        for b in buckets:
            rem = M - b
            t = combine_dual(self._mxu_parts(b, K, N),
                             self._xla_parts(rem, K, N),
                             self.spec) + t_sync
            cands.append(Decision(site, M, "act", t, m_bucket=b,
                                  ratio=f"{b}:{rem}tok"))
            # hybrid: also weight-split the bucketed part (§4.2.3)
            if N >= 2 * ALIGN and rem < b // 2:
                for frac in (0.25, 0.5, 0.75):
                    n_mxu = int(round(N * frac / ALIGN)) * ALIGN
                    if not 0 < n_mxu < N:
                        continue
                    cm, bm = self._mxu_parts(b, K, n_mxu)
                    cx1, bx1 = self._xla_parts(b, K, N - n_mxu)
                    cx2, bx2 = self._xla_parts(rem, K, N)
                    t = combine_dual((cm, bm), (cx1 + cx2, bx1 + bx2),
                                     self.spec) + t_sync
                    cands.append(Decision(site, M, "hybrid", t,
                                          n_split=n_mxu, m_bucket=b,
                                          ratio=f"{n_mxu}:{N - n_mxu}w"))
        best = min(cands, key=lambda d: d.t_us)
        return best

    # ---- stage-parallel (serving) pair --------------------------------------
    def solve_mixed(self, site: str, m_prefill: int, m_decode: int
                    ) -> Decision:
        """Cost the stage-parallel pair the mixed-batch scheduler fuses:
        ``m_decode`` decode-lane tokens on the flexible path running
        CONCURRENTLY with an ``m_prefill``-token aligned prefill chunk on
        the MXU path at this weight site. Decode is memory-bound and
        prefill compute-bound (paper §4.1), so the pair shares the
        dual-stream bandwidth pool (`combine_dual`, Memory-1) instead of
        serializing two single-stream dispatches."""
        K, N = self.table.sites[site]
        t_sync = sync_cost_us(self.sync_mode, self.spec)
        m_pre = -(-m_prefill // ALIGN) * ALIGN        # MXU stage padding
        t = combine_dual(self._mxu_parts(m_pre, K, N),
                         self._xla_parts(m_decode, K, N),
                         self.spec) + t_sync
        return Decision(site, m_prefill + m_decode, "mixed", t,
                        m_bucket=m_prefill,
                        ratio=f"{m_prefill}p:{m_decode}d")

    def mixed_gain_us(self, site: str, m_prefill: int, m_decode: int
                      ) -> float:
        """Predicted latency saved per site by fusing the pair vs running
        the two stages back-to-back (each alone on single-stream
        bandwidth, each paying its own sync)."""
        K, N = self.table.sites[site]
        t_sync = sync_cost_us(self.sync_mode, self.spec)
        m_pre = -(-m_prefill // ALIGN) * ALIGN
        serial = (combine_single(self._mxu_parts(m_pre, K, N),
                                 self.spec) + t_sync
                  + combine_single(self._xla_parts(m_decode, K, N),
                                   self.spec)
                  + t_sync)
        return serial - self.solve_mixed(site, m_prefill, m_decode).t_us

    # ---- speculative-decoding verification ----------------------------------
    def solve_verify(self, site: str, k: int, lanes: int = 1) -> Decision:
        """Plan the VERIFY site class: one speculative-decoding verification
        dispatch scores ``lanes`` lanes x (pending token + k drafts) — an
        M = lanes*(k+1) matmul at this weight site. The strategy search is
        the standard one (verification is just a matmul), but the decision
        lives in its own key space because M is chosen by the SCHEDULER
        (via K), not by the request: raising K walks verification out of
        the xla_only decode regime into act/hybrid territory, which is
        exactly the lever speculative decoding hands the solver."""
        dec = self.solve_site(site, lanes * (k + 1))
        return Decision(site=site, M=dec.M, strategy=dec.strategy,
                        t_us=dec.t_us, n_split=dec.n_split,
                        m_bucket=dec.m_bucket,
                        ratio=f"verify[k={k},lanes={lanes}]{dec.ratio}")

    def verify_gain_us(self, site: str, k: int, lanes: int = 1) -> float:
        """Predicted latency saved per site by verifying K drafts in ONE
        M = lanes*(k+1) dispatch vs emitting the same k+1 tokens as k+1
        sequential M = lanes decode dispatches (each memory-bound on the
        flexible path, each paying its own T_sync) — the analytic account
        of why speculative decoding pays on dispatch-taxed SoCs."""
        K, N = self.table.sites[site]
        t_sync = sync_cost_us(self.sync_mode, self.spec)
        serial = (k + 1) * (combine_single(
            self._xla_parts(lanes, K, N), self.spec) + t_sync)
        return serial - (self.solve_verify(site, k, lanes).t_us + t_sync)

    # ---- whole-model plan ---------------------------------------------------
    def solve(self, cfg, Ms=(1, 64, 128, 192, 256, 300, 320, 512, 1024,
                             2048, 4096), mixed_pairs=(),
              verify_ks=(), extra_ms=()) -> PartitionPlan:
        """``mixed_pairs``: (m_prefill, m_decode) serving pairs — the
        scheduler's (prefill chunk bucket, decode width) grid — solved per
        site into ``plan.mixed_decisions``. ``verify_ks``: (k, lanes)
        speculative-verification shapes, solved per site into
        ``plan.verify_decisions``. ``extra_ms``: additional token counts to
        solve alongside the standard grid — the prefix-cache scheduler
        passes its suffix-chunk lengths (block-size multiples below the
        smallest bucket) so warm-path prefill chunks resolve to solved
        decisions instead of the nearest-M fallback."""
        plan = PartitionPlan(arch=cfg.name, sync_mode=self.sync_mode,
                             weight_quant=self.weight_quant)
        all_ms = sorted(set(Ms) | set(extra_ms))
        for site in self.table.sites:
            for M in all_ms:
                plan.decisions[(site, M)] = self.solve_site(site, M)
            for (mp, md) in mixed_pairs:
                plan.mixed_decisions[(site, mp, md)] = \
                    self.solve_mixed(site, mp, md)
            for (k, lanes) in verify_ks:
                plan.verify_decisions[(site, k, lanes)] = \
                    self.solve_verify(site, k, lanes)
        plan.kv_mode = self.solve_kv_mode(cfg)
        return plan

    # ---- distributed decode layout (mesh-level partitioning) ---------------
    def solve_kv_mode(self, cfg, *, model_ax: int = 16,
                      seq_len: int = 32768, batch_per_dev: int = 8) -> str:
        """Pick KV sharding for decode: heads over the model axis (no
        collective in attention, but padded/replicated KV when n_kv_heads <
        axis) vs sequence-split KV (balanced HBM streams + tiny two-pass
        softmax all-reduce). Bytes-dominated decision — decode is Memory-1."""
        if cfg.rwkv is not None:
            return "head"        # constant-size state; no KV to split
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        bytes_el = 2
        kv_bytes_tot = 2 * seq_len * hkv * hd * bytes_el * batch_per_dev
        # Each chip streams its own HBM in both modes; the decision is
        # replication waste (head mode when heads don't divide the axis)
        # vs the tiny split-KV softmax-combine collective (seq mode).
        eff = math.gcd(hkv, model_ax)
        bw = self.spec.hbm_bw * self.spec.bw_frac_single
        t_head = (kv_bytes_tot / eff) / bw
        t_seq = (kv_bytes_tot / model_ax) / bw
        coll = 2 * cfg.n_heads * hd * bytes_el * batch_per_dev  # num+den combine
        t_seq += coll / (self.spec.ici_bw * self.spec.ici_links)
        return "head" if t_head <= t_seq else "seq"
