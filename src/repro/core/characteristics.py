"""Hardware performance characteristics — the paper's §3, ported to TPU v5e.

Two execution paths with qualitatively different cost models (the GPU/NPU
split of the paper):

  * MXU path  (≈ the paper's NPU): weight-stationary systolic model.
    - stage performance (NPU-1): every dim rounds up to 128-lane tiles;
      latency is a staircase in (M, N, K).
    - order sensitivity (NPU-2): the stationary operand is the weight; when
      the weight is large relative to the activation, tile-reload overhead
      dominates: cost(x[M,K] @ w[K,N]) != cost(w^T[N,K] @ x^T[K,M]).
    - shape sensitivity (NPU-3): weight reloads scale with ceil(K/128)*ceil(N/128),
      amortized over M — row-heavy activations run proportionally faster.
  * XLA path  (≈ the paper's GPU): flexible, any shape without recompiling,
    linear-in-FLOPs with a lower effective peak plus a fixed kernel overhead
    (GPU-1), and a large host-sync cost when the host blocks per kernel
    (GPU-2 — clFinish:400us :: JAX dispatch+block_until_ready).
  * Memory system (Memory-1): one engine's streams reach only a fraction of
    peak HBM bandwidth; two concurrent engines aggregate closer to peak.

All constants are per-chip TPU v5e unless noted and are the single source of
truth for the profiler/solver AND the roofline math.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bw: float = 819e9                # B/s
    ici_bw: float = 50e9                 # B/s per link
    ici_links: int = 4                   # 2D torus (v5e)
    vmem_bytes: int = 64 * 2 ** 20       # usable VMEM budget (conservative)
    mxu_tile: int = 128                  # systolic array edge
    n_mxu: int = 4
    dispatch_us: float = 50.0            # host->device dispatch+sync latency
    device_sync_us: float = 1.0          # on-device inter-step latency
    # Memory-1: achievable HBM fraction by concurrent stream count
    bw_frac_single: float = 0.62         # one engine (paper: 40-45/68 GB/s)
    bw_frac_dual: float = 0.90           # two engines  (paper: ~60/68 GB/s)
    # XLA-path effective compute efficiency on arbitrary shapes
    xla_eff: float = 0.45
    xla_kernel_overhead_us: float = 3.0

    @property
    def clock_hz(self) -> float:
        # peak = 2 * tile^2 * n_mxu * clock
        return self.peak_flops_bf16 / (2 * self.mxu_tile ** 2 * self.n_mxu)


V5E = TPUSpec()


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def mxu_matmul_parts(M: int, K: int, N: int, spec: TPUSpec = V5E,
                     *, bytes_per_el: int = 2,
                     w_bytes_per_el: float | None = None) -> tuple[float, int]:
    """(compute_us, hbm_bytes) for x[M,K] @ w[K,N] on the MXU path
    (weight-stationary systolic model).

    cycles = sum over (k,n) weight tiles of (reload + ceil(M/128) row-streams)
    -> stage performance from the ceils, order/shape sensitivity from the
    reload term scaling with K*N but amortizing over M.

    ``w_bytes_per_el`` decouples the weight stream from the activation dtype
    for weight-only quantization (int8 -> 1, packed int4 -> 0.5): compute
    cycles are unchanged (dequant happens in VMEM, the MXU still runs
    high-precision MACs) but the weight HBM traffic — what a memory-bound
    decode step actually pays — shrinks with the storage dtype.
    """
    if w_bytes_per_el is None:
        w_bytes_per_el = bytes_per_el
    t = spec.mxu_tile
    tm, tk, tn = _ceil(M, t), _ceil(K, t), _ceil(N, t)
    reload_cycles = t                       # systolic pipeline refill per tile
    compute_cycles = tk * tn * (reload_cycles + tm * t) / spec.n_mxu
    compute_us = compute_cycles / spec.clock_hz * 1e6
    # memory: activations once, weights once (or more if > VMEM working set),
    # outputs once
    w_bytes = K * N * w_bytes_per_el
    x_bytes = M * K * bytes_per_el
    o_bytes = M * N * bytes_per_el
    reload_factor = 1.0 if w_bytes + x_bytes < spec.vmem_bytes else \
        max(1.0, tm / 8)                   # streaming reloads when oversized
    nbytes = int(x_bytes + w_bytes * min(reload_factor, 4.0) + o_bytes)
    return compute_us, nbytes


def xla_matmul_parts(M: int, K: int, N: int, spec: TPUSpec = V5E,
                     *, bytes_per_el: int = 2,
                     w_bytes_per_el: float | None = None) -> tuple[float, int]:
    """(compute_us incl. kernel overhead, hbm_bytes) for the flexible XLA
    path: linear-in-FLOPs (GPU-1) at a lower effective peak, any shape.
    ``w_bytes_per_el`` — see :func:`mxu_matmul_parts`."""
    if w_bytes_per_el is None:
        w_bytes_per_el = bytes_per_el
    flops = 2.0 * M * K * N
    nbytes = (M * K + M * N) * bytes_per_el + K * N * w_bytes_per_el
    compute_us = flops / (spec.peak_flops_bf16 * spec.xla_eff) * 1e6 \
        + spec.xla_kernel_overhead_us
    return compute_us, int(nbytes)


def combine_single(parts: tuple[float, int], spec: TPUSpec = V5E) -> float:
    """Latency of one path running alone (single-stream bandwidth)."""
    c, b = parts
    return max(c, b / (spec.hbm_bw * spec.bw_frac_single) * 1e6)


def combine_dual(parts_a: tuple[float, int], parts_b: tuple[float, int],
                 spec: TPUSpec = V5E) -> float:
    """Latency of two concurrent paths sharing the aggregated-bandwidth pool
    (Memory-1: dual streams reach bw_frac_dual of peak)."""
    ca, ba = parts_a
    cb, bb = parts_b
    mem_us = (ba + bb) / (spec.hbm_bw * spec.bw_frac_dual) * 1e6
    return max(ca, cb, mem_us)


WEIGHT_BYTES_PER_EL = {None: 2.0, "int8": 1.0, "w4a16": 0.5}


def mxu_matmul_time_us(M: int, K: int, N: int, spec: TPUSpec = V5E,
                       *, bytes_per_el: int = 2,
                       w_bytes_per_el: float | None = None) -> float:
    return combine_single(mxu_matmul_parts(M, K, N, spec,
                                           bytes_per_el=bytes_per_el,
                                           w_bytes_per_el=w_bytes_per_el), spec)


def xla_matmul_time_us(M: int, K: int, N: int, spec: TPUSpec = V5E,
                       *, bytes_per_el: int = 2,
                       w_bytes_per_el: float | None = None) -> float:
    return combine_single(xla_matmul_parts(M, K, N, spec,
                                           bytes_per_el=bytes_per_el,
                                           w_bytes_per_el=w_bytes_per_el), spec)


def dual_path_memory_time_us(bytes_a: int, bytes_b: int,
                             spec: TPUSpec = V5E) -> float:
    """Memory-1: two concurrent streams share an aggregated-bandwidth pool."""
    return (bytes_a + bytes_b) / (spec.hbm_bw * spec.bw_frac_dual) * 1e6


def sync_cost_us(mode: str, spec: TPUSpec = V5E) -> float:
    """GPU-2: 'host' = blocking host sync per kernel (clFinish analogue);
    'fast' = on-device chaining (the paper's flag-polling analogue)."""
    return spec.dispatch_us if mode == "host" else spec.device_sync_us


def compile_time_model_us(M: int, K: int, N: int) -> float:
    """'NPU graph generation' analogue (paper Fig 8): per-graph build latency,
    affine in sequence length. Calibrated to the paper's own measurements
    (~100ms/graph at S=135, ~500ms/graph at S=1000). NOTE: measured XLA
    trace+compile on this backend (benchmarks/bench_compile_cost.py) is ~10x
    LARGER — online-prepare is even less viable on the TPU target than on
    QNN, strengthening the case for bucketed static graphs (EXPERIMENTS.md)."""
    return 5e4 + 350.0 * M
