"""Distributed split-KV decode attention (flash-decoding over the mesh).

The KV cache shards along the SEQUENCE dim over the model axis (the
mesh-level form of activation-centric partitioning). Per decode step, inside
a shard_map over the whole mesh:

  * the shard owning slot ``idx`` writes the new K/V locally (no cross-shard
    cache movement — this kills the involuntary-full-rematerialization
    collectives GSPMD emits for a dynamic-update-slice on a sharded dim,
    §Perf decode/i3);
  * every shard computes attention over its local KV slice;
  * partial softmax stats combine with a global pmax + two psums of
    [B, Hq, D]-sized tensors (~100 KB — vs gigabytes of cache traffic).

All shards aggregate their HBM streams simultaneously — the paper's
Memory-1 bandwidth-aggregation insight, applied across chips.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

NEG_INF = -1e30


def combine_split_softmax(s, v_local, axis_name=None):
    """Numerically-stable softmax combine of per-shard attention partials —
    the pmax + 2×psum pattern (one [B, Hkv, G] pmax, then psums of the
    [B, Hq, D]-sized numerator and the [B, Hkv, G] denominator).

    ``s``: local masked scores [B, Hkv, G, K_local] (NEG_INF outside range);
    ``v_local``: local values [B, K_local, Hkv, D]. With ``axis_name=None``
    (single shard / unit tests) the collectives degenerate to identity and
    this is exactly a blockwise-stable softmax-weighted sum.

    Returns fp32 [B, Hkv, G, D].
    """
    m_l = s.max(axis=-1)                                # [B, Hkv, G]
    m_g = jax.lax.pmax(m_l, axis_name) if axis_name else m_l
    p = jnp.exp(s - m_g[..., None])
    den = p.sum(axis=-1)
    num = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_local.dtype), v_local,
                     preferred_element_type=jnp.float32)
    if axis_name:
        den = jax.lax.psum(den, axis_name)
        num = jax.lax.psum(num, axis_name)
    return num / jnp.where(den == 0.0, 1.0, den)[..., None]


def _mesh_axes():
    from repro.distributed.compat import get_mesh
    mesh = get_mesh()
    names = mesh.axis_names
    data = tuple(n for n in names if n != "model")
    return mesh, data


def split_kv_decode_update_attend(q, k_new, v_new, k_cache, v_cache, idx):
    """q,k_new,v_new: [B, 1, H*, D] (Hq for q, Hkv for kv); caches
    [B, Smax, Hkv, D] seq-sharded over 'model', batch over the data axes.
    idx: scalar int32 write slot (= query position).
    Returns (out [B, 1, Hq, D], new_k_cache, new_v_cache)."""
    mesh, data_axes = _mesh_axes()
    B, _, Hq, D = q.shape
    Hkv = k_new.shape[2]
    Smax = k_cache.shape[1]
    n_shards = mesh.shape["model"]
    if Smax % n_shards != 0:
        raise ValueError(
            f"split-KV cache length Smax={Smax} is not divisible by the "
            f"model-axis size {n_shards}: the trailing {Smax % n_shards} "
            "slots would never be attended over and writes to them would be "
            "silently dropped. Pad Smax to a multiple of the shard count.")
    chunk = Smax // n_shards
    scale = 1.0 / math.sqrt(D)
    G = Hq // Hkv

    qs = P(data_axes, None, None, None)
    cs = P(data_axes, "model", None, None)

    def local(qx, kn, vn, kc, vc, i):
        Bl = qx.shape[0]                 # local (per-data-shard) batch
        sid = jax.lax.axis_index("model")
        start = sid * chunk
        pos = i - start
        in_range = (pos >= 0) & (pos < chunk)

        def write(c, new):
            upd = jax.lax.dynamic_update_slice(
                c, new.astype(c.dtype),
                (0, jnp.clip(pos, 0, chunk - 1), 0, 0))
            return jnp.where(in_range, upd, c)

        kc = write(kc, kn)
        vc = write(vc, vn)

        # local attention over this shard's KV slice. NO .astype on the
        # cache operands: fp32 copies of K/V would dominate HBM traffic
        # (§Perf decode/i4) — accumulate in fp32 via preferred_element_type.
        qg = qx.reshape(Bl, Hkv, G, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = start + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.where(kv_pos[None, None, None, :] <= i, s, NEG_INF)
        out = combine_split_softmax(s, vc, "model")
        return out.reshape(Bl, 1, Hq, D).astype(qx.dtype), kc, vc

    return shard_map(
        local, mesh=mesh,
        in_specs=(qs, qs, qs, cs, cs, P()),
        out_specs=(qs, cs, cs),
        check_vma=False,
    )(q, k_new, v_new, k_cache, v_cache, idx)
