"""Gradient compression for data-parallel reductions (distributed-optimization
trick for 1000+-node DP: 4x less DCI traffic on the cross-pod hop).

int8 symmetric quantization with per-tensor scale and ERROR FEEDBACK: the
quantization residual is carried and added back next step, so compression
introduces no bias accumulation (convergence-safe; standard EF-SGD result).

``compressed_psum(g, axis)`` is the shard_map building block; the jit-level
``compress/decompress`` pair wraps any all-reduce the trainer performs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_grads_with_feedback(grads: Any, error: Any) -> tuple[Any, Any]:
    """Returns (quantized-grads-as-float, new_error). Apply BEFORE the DP
    all-reduce; the reduction then moves int8-precision payloads."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), (g32 - deq)

    out = jax.tree.map(one, grads, error)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_err


def init_error(grads_shape: Any) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """shard_map collective: int8-quantize, psum, dequantize. The scale is
    max-combined first so the sum stays within range."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0) * n
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale * n), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)
