"""Pipeline parallelism: GPipe-style microbatch schedule over a "stage" axis,
expressed with shard_map + collective_permute (jax-native; no NCCL-style
point-to-point emulation).

Layers are split into ``n_stages`` contiguous groups. A shard_map over the
stage axis runs ``n_micro + n_stages - 1`` ticks; each tick every stage
processes one microbatch slice and ppermutes its activation to the next
stage. Bubble fraction = (S-1)/(M+S-1), surfaced by ``pipeline_stats`` so the
solver/roofline can weigh PP against TP for deep models. Used as an optional
config (``pp=N``) in the trainer; tested end-to-end in
tests/test_distributed.py on a host mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_stats(n_micro: int, n_stages: int) -> dict:
    ticks = n_micro + n_stages - 1
    return {"ticks": ticks,
            "bubble_fraction": (n_stages - 1) / ticks}


def make_pipeline_forward(layer_fn: Callable, n_stages: int, n_micro: int,
                          mesh, *, stage_axis: str = "stage"):
    """layer_fn(stage_params, x) -> x, applied per stage.

    stage_params: pytree stacked on a leading stage dim (sharded over
    ``stage_axis``); x: [n_micro, mb, ...] microbatched input living on
    stage 0. Returns outputs [n_micro, mb, ...] gathered on the last stage
    then broadcast (simple GPipe; interleaved 1F1B left as config).
    """

    def stage_prog(params_s, x_s):
        # params_s: this stage's params (leading dim 1); x_s: [n_micro, mb, ...]
        params_s = jax.tree.map(lambda a: a[0], params_s)
        sid = jax.lax.axis_index(stage_axis)
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_s[0])
        outs = jnp.zeros_like(x_s)

        def tick(c, t):
            buf, outs = c
            mb_in = t - sid                      # microbatch index at this stage
            feed = jnp.where(mb_in >= 0, jnp.clip(mb_in, 0, n_micro - 1), 0)
            x_in = jnp.where(sid == 0, x_s[feed], buf)
            active = (mb_in >= 0) & (mb_in < n_micro)
            y = layer_fn(params_s, x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: o.at[feed].set(y), lambda o: o, outs)
            # everyone hands activations down the ring
            buf = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # broadcast final outputs from the last stage to all stages
        outs = jax.lax.ppermute(
            outs, stage_axis,
            [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)]) \
            if n_stages > 1 else outs
        return outs

    return shard_map(
        stage_prog, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_vma=False)
