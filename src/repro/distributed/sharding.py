"""Sharding rules: DP / FSDP / TP / EP / SP, driven by param-path pattern match.

Conventions (single- or multi-pod; D = compound data axes, M = ("model",)):
  * weights: TP dim over M, FSDP dim over D  (ZeRO-3-style: optimizer states
    shard identically; scan-over-layers turns the per-layer FSDP all-gather
    into an overlapped weight prefetch).
  * activations between blocks: batch over D, sequence over M (Megatron-style
    sequence parallelism) — applied via ``hidden_constraint`` inside models.
  * MoE experts over M (EP); router replicated.
  * KV caches: batch over D; heads over M ("head" mode) or sequence over M
    ("seq" mode = distributed split-KV flash-decoding). The HeteroInfer solver
    picks the mode per (arch, shape); see repro.core.solver.
"""
from __future__ import annotations

import contextvars
import re
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


# -------------------------------------------------- activation constraints --

_ACT_SPEC: contextvars.ContextVar = contextvars.ContextVar("act_spec", default=None)


class activation_sharding:
    """Context manager installing the between-blocks hidden-state spec."""

    def __init__(self, spec: Optional[P]):
        self.spec = spec

    def __enter__(self):
        self.tok = _ACT_SPEC.set(self.spec)
        return self

    def __exit__(self, *exc):
        _ACT_SPEC.reset(self.tok)
        return False


def hidden_constraint(x: jax.Array) -> jax.Array:
    spec = _ACT_SPEC.get()
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


_SPLIT_KV: contextvars.ContextVar = contextvars.ContextVar("split_kv",
                                                           default=False)


class split_kv_enabled:
    """Trace-time switch: decode attention uses the shard_map split-KV path
    (sequence-sharded cache, owner-local writes, psum softmax combine)."""

    def __init__(self, enable: bool):
        self.enable = enable

    def __enter__(self):
        self.tok = _SPLIT_KV.set(self.enable)
        return self

    def __exit__(self, *exc):
        _SPLIT_KV.reset(self.tok)
        return False


def split_kv_active() -> bool:
    return _SPLIT_KV.get()


def logits_constraint(x: jax.Array) -> jax.Array:
    """Vocab-sharded logits [B, c, V]: batch over data axes, V over model.
    Only active when an activation spec is installed (i.e., running under a
    mesh); single-device tests are untouched."""
    spec = _ACT_SPEC.get()
    if spec is None:
        return x
    batch_ax = list(spec)[0] if len(list(spec)) else None
    return jax.lax.with_sharding_constraint(x, P(batch_ax, None, "model"))


# --------------------------------------------------------- parameter rules --

def _param_rules(D, M):
    """(regex over param path) -> PartitionSpec. First match wins.
    Paths look like 'layers/attn/wq', 'mamba/in_proj', 'shared/ffn/w_down'."""
    return [
        # --- embeddings / head. The embed table shards on d_model ONLY:
        # vocab-sharding turns the token gather (and the scatter-add of its
        # gradient) into an unsharded fp32 table materialization under GSPMD
        # (§Perf train/i3 — 2.3GB x many copies at dbrx scale).
        (r"^embed$",                 P(None, D)),
        (r"^head$",                  P(D, M)),
        # --- MoE (stacked [L, E, ...])
        (r"moe/router$",             P(None, D, None)),
        (r"moe/(w_gate|w_up)$",      P(None, M, D, None)),
        (r"moe/w_down$",             P(None, M, None, D)),
        (r"moe/shared_gate$",        P()),
        (r"moe/shared/(w_gate|w_up)$", P(None, D, M)),
        (r"moe/shared/w_down$",      P(None, M, D)),
        # --- attention (stacked [L, d, h*hd] or shared [d, h*hd])
        (r"layers/attn/(wq|wk|wv)$", P(None, D, M)),
        (r"layers/attn/wo$",         P(None, M, D)),
        (r"shared/attn/(wq|wk|wv)$", P(D, M)),
        (r"shared/attn/wo$",         P(M, D)),
        # --- dense FFN
        (r"layers/ffn/(w_gate|w_up)$", P(None, D, M)),
        (r"layers/ffn/w_down$",      P(None, M, D)),
        (r"shared/ffn/(w_gate|w_up)$", P(D, M)),
        (r"shared/ffn/w_down$",      P(M, D)),
        # --- mamba2
        (r"mamba/in_proj$",          P(None, D, None)),
        (r"mamba/out_proj$",         P(None, M, D)),
        (r"mamba/(conv_w|conv_b|A_log|dt_bias|D|gate_norm|norm)$", P()),
        # --- rwkv6
        (r"layers/(wr|wk|wv|wg)$",   P(None, D, M)),
        (r"layers/wo$",              P(None, M, D)),
        (r"layers/wk_ffn$",          P(None, D, M)),
        (r"layers/wv_ffn$",          P(None, M, D)),
        (r"layers/wr_ffn$",          P(None, D, M)),
        (r"layers/(w_base|w_lora_a|w_lora_b|u|mix|mix_ffn)$", P()),
        # --- everything else (norms, scales, biases): replicate
        (r".*",                      P()),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
    return "/".join(parts)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class ShardingDropWarning(UserWarning):
    """A requested sharding was silently turned into replication."""


_SANITIZE_WARNED: set = set()


def sanitize_spec(spec: P, shape: tuple, mesh, *, dropped: list = None) -> P:
    """Drop sharding on any dim the mesh axes don't divide evenly (pjit
    argument shardings require exact divisibility). This is the generic
    guard for e.g. vocab=504, n_kv_heads=8 on a 16-wide model axis, batch=1.

    Dropping is NOT silent: each distinct (dim, size, axes) drop emits a
    one-time ``ShardingDropWarning`` (an intended shard quietly becoming
    full replication is a capacity bug, not a preference), and callers that
    must *know* — e.g. TP serving asserting its KV-head dim actually sharded
    — can pass ``dropped=[]`` to receive the dim indices that replicated.
    """
    entries = list(spec) + [None] * (len(shape) - len(list(spec)))
    out = []
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            if dropped is not None:
                dropped.append(i)
            key = (i, dim, ax if isinstance(ax, str) else tuple(ax))
            if key not in _SANITIZE_WARNED:
                _SANITIZE_WARNED.add(key)
                warnings.warn(
                    f"sanitize_spec: dim {i} (size {dim}) is not divisible "
                    f"by mesh axes {ax!r} (size {_axis_size(mesh, ax)}); "
                    "dropping the sharding — this dim will REPLICATE",
                    ShardingDropWarning, stacklevel=2)
            ax = None
        out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_specs(params_shape: Any, mesh, *, fsdp: bool = True) -> Any:
    """Map an eval_shape'd params pytree -> pytree of PartitionSpec.

    fsdp=False (serving): weights shard over the model axis only and
    REPLICATE over data — decode must not all-gather parameters per token
    (perf iteration decode/i1 in EXPERIMENTS.md §Perf).
    """
    D, M = (data_axes(mesh) if fsdp else None), "model"
    rules = [(re.compile(pat), spec) for pat, spec in _param_rules(D, M)]
    m_size = mesh.shape["model"]

    def spec_for(path, leaf):
        s = _path_str(path)
        # MoE expert tensors: EP over model when E divides, else TP on d_ff
        if re.search(r"moe/(w_gate|w_up)$", s):
            E = leaf.shape[1]
            spec = (P(None, M, D, None) if E % m_size == 0
                    else P(None, None, D, M))
            return sanitize_spec(spec, leaf.shape, mesh)
        if re.search(r"moe/w_down$", s):
            E = leaf.shape[1]
            spec = (P(None, M, None, D) if E % m_size == 0
                    else P(None, None, M, D))
            return sanitize_spec(spec, leaf.shape, mesh)
        for pat, spec in rules:
            if pat.search(s):
                if len([a for a in spec]) > leaf.ndim:
                    return P()
                return sanitize_spec(spec, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def param_shardings(params_shape: Any, mesh, *, fsdp: bool = True) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, mesh, fsdp=fsdp))


# ------------------------------------------------------------ cache rules --

def cache_specs(cache_shape: Any, mesh, cfg, *, kv_mode: str = "auto") -> Any:
    """KV/state cache sharding. kv_mode: 'head' | 'seq' | 'auto'.

    'auto' = heads over model when n_kv_heads divides the model-axis size
    (zero padding waste), else sequence-sharded split-KV.
    """
    D = data_axes(mesh)
    m_size = mesh.shape["model"]
    if kv_mode == "auto":
        kv_mode = "head" if cfg.n_kv_heads % m_size == 0 else "seq"

    def spec_for(path, leaf):
        name = _path_str(path)
        if name in ("k", "v"):          # [L, B, Smax, Hkv, hd]
            if kv_mode == "head":
                spec = P(None, D, None, "model", None)
            else:
                spec = P(None, D, "model", None, None)
        elif name == "ssm":             # [L, B, nh, hd, N]
            spec = P(None, D, "model", None, None)
        elif name == "conv":            # [L, B, K-1, conv_dim]
            spec = P(None, D, None, "model")
        elif name == "wkv":             # [L, B, H, hd, hd]
            spec = P(None, D, None, "model", None)
        elif name.startswith("shift"):  # [L, B, D]
            spec = P(None, D, "model")
        else:
            return P()                  # index etc.
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def cache_shardings(cache_shape, mesh, cfg, *, kv_mode="auto"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cache_shape, mesh, cfg, kv_mode=kv_mode))


# ------------------------------------------------------------- input rules --

def batch_spec(mesh, ndim: int = 2) -> P:
    """Token batches: batch dim over compound data axes."""
    D = data_axes(mesh)
    return P(D, *([None] * (ndim - 1)))


def batch_sharding(mesh, shape: tuple) -> NamedSharding:
    """Batch sharding sanitized against the concrete shape (batch=1 cells
    replicate instead of failing divisibility)."""
    return NamedSharding(mesh, sanitize_spec(batch_spec(mesh, len(shape)),
                                             shape, mesh))


def hidden_spec(mesh, *, seq_shard: bool = True) -> P:
    D = data_axes(mesh)
    return P(D, "model" if seq_shard else None, None)
