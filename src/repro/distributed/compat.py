"""JAX version compatibility for the distributed layer.

The repo targets the modern ``jax.shard_map`` / ``check_vma`` spelling; on
older runtimes (0.4.x) that API lives in ``jax.experimental.shard_map`` and
the replication-check kwarg is ``check_rep``. Route every call through here.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    _shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
# independently of the top-level promotion, so probe the signature
import inspect

_params = inspect.signature(_shard_map).parameters
_CHECK_KW = "check_vma" if "check_vma" in _params else "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    kw = {} if check_vma else {_CHECK_KW: False}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def get_mesh():
    """The ambient mesh set by :func:`set_mesh` (abstract mesh on new JAX,
    the thread-resources physical mesh on 0.4.x)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def set_mesh(mesh):
    """Context manager entering ``mesh`` (``jax.sharding.set_mesh`` on new
    JAX; the ``Mesh`` object itself is a context manager on 0.4.x)."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh
