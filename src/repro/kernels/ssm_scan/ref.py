"""Pure-jnp oracle: one SSD (Mamba2) chunk step.

Given a chunk of dt-weighted inputs xb [B,L,nh,hd], in/out projections
B_,C_ [B,L,N], inclusive log-decay cumsum seg [B,L,nh] and incoming state
S_prev [B,nh,hd,N], produce (y [B,L,nh,hd], S_new). Matches
repro.models.mamba2.ssd_chunked's scan body exactly.
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(xb, B_, C_, seg, S_prev):
    L = xb.shape[1]
    tri = jnp.tril(jnp.ones((L, L), bool))
    CB = jnp.einsum("bin,bjn->bij", C_, B_)
    dec = jnp.exp(seg[:, :, None, :] - seg[:, None, :, :])
    att = CB[..., None] * jnp.where(tri[None, :, :, None], dec, 0.0)
    y = jnp.einsum("bijh,bjhp->bihp", att, xb)
    y = y + jnp.einsum("bin,bhpn->bihp", C_, S_prev) * jnp.exp(seg)[..., None]
    tot = seg[:, -1, :]
    w_in = jnp.exp(tot[:, None, :] - seg)
    S_new = (jnp.exp(tot)[:, :, None, None] * S_prev
             + jnp.einsum("bjhp,bjn,bjh->bhpn", xb, B_, w_in))
    return y, S_new
