"""jit'd wrapper: full SSD scan = lax.scan of the Pallas chunk kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, A, B_, C_, *, chunk: int = 256, interpret: bool = True):
    """Same contract as models.mamba2.ssd_chunked, Pallas chunk compute.
    xh [B,S,nh,hd]; dt [B,S,nh] (post-softplus); A [nh] (<0); B_,C_ [B,S,N].
    """
    Bb, S, nh, hd = xh.shape
    N = B_.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    da = (dt * A[None, None, :]).astype(jnp.float32)
    xb = (xh * dt[..., None]).astype(jnp.float32)
    rs = lambda a: a.reshape(Bb, nc, L, *a.shape[2:]).transpose(
        1, 0, 2, *range(3, a.ndim + 1))
    da_c, xb_c = rs(da), rs(xb)
    B_c, C_c = rs(B_.astype(jnp.float32)), rs(C_.astype(jnp.float32))
    seg = jnp.cumsum(da_c, axis=2)

    def step(S_prev, xs):
        xb_i, B_i, C_i, seg_i = xs
        y, S_new = ssd_chunk_pallas(xb_i, B_i, C_i, seg_i, S_prev,
                                    interpret=interpret)
        return S_new, y

    S0 = jnp.zeros((Bb, nh, hd, N), jnp.float32)
    S_fin, y = jax.lax.scan(step, S0, (xb_c, B_c, C_c, seg))
    return y.transpose(1, 0, 2, 3, 4).reshape(Bb, S, nh, hd), S_fin
