"""SSD (Mamba2) chunk-step kernel — Pallas TPU.

One grid cell = one (batch, head) pair; the whole chunk's working set lives
in VMEM: CB [L,L] via MXU, per-head scalar decay applied on the VPU, three
more MXU matmuls for the intra-chunk output, state read-out and state
update. L=256, N=64, hd=64 keeps every matmul dim 64/128-aligned and the
VMEM footprint ~1.2 MB/cell.

This is the compute hot spot of the zamba2 cells; the chunk scan itself
(state passing) stays in jax.lax.scan — recurrences don't cross the kernel
boundary, exactly like the paper's per-operator NPU offload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_kernel(xb_ref, b_ref, c_ref, seg_ref, sprev_ref, y_ref, snew_ref,
                  *, L: int):
    xb = xb_ref[0, :, 0, :].astype(jnp.float32)        # [L, hd]
    B_ = b_ref[0].astype(jnp.float32)                  # [L, N]
    C_ = c_ref[0].astype(jnp.float32)                  # [L, N]
    seg = seg_ref[0, :, 0].astype(jnp.float32)         # [L]
    S_prev = sprev_ref[0, 0].astype(jnp.float32)       # [hd, N]

    CB = jnp.dot(C_, B_.T, preferred_element_type=jnp.float32)   # [L, L]
    dec = jnp.exp(seg[:, None] - seg[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(jj <= ii, CB * dec, 0.0)
    y = jnp.dot(att, xb, preferred_element_type=jnp.float32)     # intra
    y = y + jnp.dot(C_, S_prev.T,
                    preferred_element_type=jnp.float32) * jnp.exp(seg)[:, None]

    tot = seg[L - 1]
    w_in = jnp.exp(tot - seg)                          # [L] (<=0 exponents)
    S_new = (jnp.exp(tot) * S_prev
             + jnp.dot((xb * w_in[:, None]).T, B_,
                       preferred_element_type=jnp.float32))      # [hd, N]

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    snew_ref[0, 0] = S_new.astype(snew_ref.dtype)


def ssd_chunk_pallas(xb, B_, C_, seg, S_prev, *, interpret: bool = True):
    """xb [B,L,nh,hd]; B_,C_ [B,L,N]; seg [B,L,nh]; S_prev [B,nh,hd,N].
    Returns (y [B,L,nh,hd], S_new [B,nh,hd,N])."""
    Bb, L, nh, hd = xb.shape
    N = B_.shape[-1]
    kern = functools.partial(_chunk_kernel, L=L)
    y, S_new = pl.pallas_call(
        kern,
        grid=(Bb, nh),
        in_specs=[
            pl.BlockSpec((1, L, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, L, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, L, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, L, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nh, hd, N), jnp.float32),
        ],
        interpret=interpret,
    )(xb, B_, C_, seg, S_prev)
    return y, S_new
