"""MXU-path blocked matmul — the TPU analogue of the paper's NPU engine.

Pallas TPU kernel with explicit BlockSpec VMEM tiling. Two grid orders expose
the paper's order-sensitivity on real silicon:

  * ``stationary="weight"``  — grid (n, k, m), m innermost: the (bk x bn)
    weight tile stays resident in VMEM while activations stream through —
    the systolic "weight stall" regime (paper Fig 2). Output blocks are
    revisited per k-step, so partial sums round-trip HBM: cheap when M is
    large (weight reuse dominates), expensive when M is small — exactly
    NPU-2/NPU-3 (order/shape sensitivity).
  * ``stationary="output"`` — grid (m, n, k), k innermost: the fp32
    accumulator lives in a VMEM scratch and is written once; weight tiles
    reload every k-step.

Weight-only quantization (the paper's W4A16 stance): int8 weights + per-column
fp32 scales are dequantized tile-by-tile in VMEM; activations stay bf16/f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM, DEFAULT_BK, DEFAULT_BN = 128, 128, 128


def _mm_kernel_output_stationary(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    """grid (m, n, k); acc scratch in VMEM; single output visit."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_weight_stationary(x_ref, w_ref, o_ref, *, nk: int):
    """grid (n, k, m); weight tile constant over innermost m sweep.
    Output revisited per k -> read-modify-write accumulate in out dtype."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _mm_kernel_quant(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    """Output-stationary int8-weight matmul with in-VMEM dequant."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jax.Array, w: jax.Array, *,
                  bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                  bn: int = DEFAULT_BN, stationary: str = "output",
                  out_dtype=None, interpret: bool = True) -> jax.Array:
    """x [M,K] @ w [K,N]. Dims must be multiples of the block sizes — this is
    the 'static graph' constraint of the MXU path (the NPU analogue); the
    HeteroInfer engine routes misaligned remainders to the XLA path instead."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"misaligned ({M},{K},{N}) for blocks ({bm},{bk},{bn})"
    out_dtype = out_dtype or x.dtype
    nk = K // bk

    if stationary == "weight":
        grid = (N // bn, nk, M // bm)
        return pl.pallas_call(
            functools.partial(_mm_kernel_weight_stationary, nk=nk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda n, k, m: (m, k)),
                pl.BlockSpec((bk, bn), lambda n, k, m: (k, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda n, k, m: (m, n)),
            out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
            interpret=interpret,
        )(x, w).astype(out_dtype)

    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_mm_kernel_output_stationary, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _mm_kernel_q4(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    """Output-stationary W4A16 matmul: two int4 weights packed per int8
    byte along K (the paper's storage format); nibbles are unpacked and
    dequantized in VMEM, activations stay high-precision."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...]                                  # int8 [bk//2, bn]
    lo = jnp.left_shift(packed, 4) >> 4                  # sign-extended low
    hi = packed >> 4                                     # arithmetic high
    bk2, bn = packed.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn) # interleaved K
    w = w.astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def q4_matmul_pallas(x: jax.Array, wq4: jax.Array, scale: jax.Array, *,
                     bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                     bn: int = DEFAULT_BN, out_dtype=None,
                     interpret: bool = True) -> jax.Array:
    """x [M,K] @ dequant(wq4 int8-packed [K//2,N], scale f32 [N]).
    K-order inside wq4: row r holds original rows (2r, 2r+1) as (lo, hi)."""
    M, K = x.shape
    K2, N = wq4.shape
    assert K == 2 * K2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % 2 == 0
    out_dtype = out_dtype or x.dtype
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel_q4, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk // 2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wq4, scale[None, :])


def quant_matmul_pallas(x: jax.Array, wq: jax.Array, scale: jax.Array, *,
                        bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                        bn: int = DEFAULT_BN, out_dtype=None,
                        interpret: bool = True) -> jax.Array:
    """x [M,K] @ dequant(wq int8 [K,N], scale f32 [N])."""
    M, K = x.shape
    _, N = wq.shape
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    out_dtype = out_dtype or x.dtype
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel_quant, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wq, scale[None, :])
