"""jit'd public wrappers for the MXU-path matmul (batch-flattening, dtype policy)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import matmul_pallas, quant_matmul_pallas


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


@partial(jax.jit, static_argnames=("bm", "bk", "bn", "stationary", "interpret"))
def mxu_matmul(x: jax.Array, w: jax.Array, *, bm=128, bk=128, bn=128,
               stationary: str = "output", interpret: bool = True) -> jax.Array:
    """[..., K] @ [K, N] on the aligned MXU path. Shapes must be aligned."""
    x2, lead = _flatten_leading(x)
    y = matmul_pallas(x2, w, bm=bm, bk=bk, bn=bn, stationary=stationary,
                      out_dtype=x.dtype, interpret=interpret)
    return y.reshape(*lead, w.shape[-1])


@partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def mxu_quant_matmul(x: jax.Array, wq: jax.Array, scale: jax.Array, *,
                     bm=128, bk=128, bn=128, interpret: bool = True) -> jax.Array:
    x2, lead = _flatten_leading(x)
    y = quant_matmul_pallas(x2, wq, scale, bm=bm, bk=bk, bn=bn,
                            out_dtype=x.dtype, interpret=interpret)
    return y.reshape(*lead, wq.shape[-1])


def quantize_weight(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-output-channel symmetric int8 weight quantization (W8A16-style)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]),
                  -127, 127).astype(jnp.int8)
    return wq, scale.astype(jnp.float32)


def quantize_weight_int4(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """W4A16 (the paper's deployment format): per-column symmetric int4,
    two weights packed per int8 byte along K (rows 2r, 2r+1 -> lo, hi).

    Odd K is zero-padded to K+1 before packing (the pad row quantizes to
    code 0, so dequant of the padded row is exactly zero); callers that
    need the logical K back pass it to :func:`dequant_int4_ref`.

    The int4 code range is asymmetric ([-8, 7]): when a column's
    max-magnitude entry is negative and no positive entry would clip at
    the wider step, amax/8 is the better scale — it maps the extreme to
    the -8 code exactly instead of clipping it at -7 with amax/7.
    """
    w = w.astype(jnp.float32)
    K, N = w.shape
    if K % 2:
        w = jnp.concatenate([w, jnp.zeros((1, N), jnp.float32)], axis=0)
    pos = jnp.max(jnp.maximum(w, 0.0), axis=0)
    neg = jnp.max(jnp.maximum(-w, 0.0), axis=0)
    amax = jnp.maximum(pos, neg)
    # amax/8 is usable iff the largest positive still rounds inside +7,
    # i.e. pos/(amax/8) < 7.5  <=>  pos < 0.9375 * amax (== neg here).
    scale = jnp.where(pos < 0.9375 * neg, amax / 8.0, amax / 7.0)
    scale = jnp.where(amax > 0, scale, 1.0)
    q = jnp.clip(jnp.round(w / scale[None, :]), -8, 7).astype(jnp.int8)
    lo = q[0::2] & 0x0F
    hi = q[1::2] & 0x0F
    packed = (lo | (hi << 4)).astype(jnp.int8)
    return packed, scale.astype(jnp.float32)


@partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def mxu_q4_matmul(x: jax.Array, wq4: jax.Array, scale: jax.Array, *,
                  bm=128, bk=128, bn=128, interpret: bool = True) -> jax.Array:
    from .kernel import q4_matmul_pallas
    x2, lead = _flatten_leading(x)
    y = q4_matmul_pallas(x2, wq4, scale, bm=bm, bk=bk, bn=bn,
                         out_dtype=x.dtype, interpret=interpret)
    return y.reshape(*lead, wq4.shape[-1])


def dequant_int4_ref(wq4: jax.Array, scale: jax.Array,
                     k: int | None = None) -> jax.Array:
    """Unpack oracle for tests. ``k`` recovers the logical contraction dim
    when the original K was odd (the packer zero-pads to even)."""
    lo = (jnp.left_shift(wq4, 4) >> 4).astype(jnp.float32)
    hi = (wq4 >> 4).astype(jnp.float32)
    K2, N = wq4.shape
    q = jnp.stack([lo, hi], axis=1).reshape(2 * K2, N)
    if k is not None:
        q = q[:k]
    return q * scale[None, :]
