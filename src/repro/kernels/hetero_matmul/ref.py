"""Pure-jnp oracle for the hetero (MXU-path) matmul."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, w, *, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)


def quant_matmul_ref(x, wq, scale, *, out_dtype=None):
    """Weight-only quantized matmul oracle: wq int8 [K,N], scale f32 [N]."""
    out_dtype = out_dtype or x.dtype
    w = wq.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)
