"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, length):
    """q [B,Hq,D]; k/v_cache [B,Smax,Hkv,D]; length scalar int (valid prefix).
    Returns [B,Hq,D]."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(S)[None, None, None, :] < length
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
