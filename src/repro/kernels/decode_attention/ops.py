"""jit'd wrapper for split-KV decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: bool = True):
    D = q.shape[-1]
    Dp = -(-D // 128) * 128
    if Dp != D:
        padf = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, Dp - D)])
        q = padf(q) * (Dp / D) ** 0.5
        k_cache, v_cache = padf(k_cache), padf(v_cache)
    out = decode_attention_pallas(q, k_cache, v_cache, length,
                                  block_k=block_k, interpret=interpret)
    return out[..., :D]
