"""Split-KV decode attention (flash-decoding) — Pallas TPU.

Decode is the paper's memory-bound phase (Memory-1): the whole KV cache is
streamed once from HBM per token. The kernel tiles the KV sequence across the
grid so multiple blocks' HBM streams overlap (the TPU analogue of the paper's
"two engines aggregate more bandwidth than one"), carrying online-softmax
stats in VMEM. Blocks past ``length`` are skipped entirely via ``pl.when`` —
compute AND the HBM stream — using a scalar-prefetch length operand.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int, n_kv: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)      # skip fully-invalid KV blocks
    def _():
        q = q_ref[0].astype(jnp.float32)            # [G, D]
        k = k_ref[0].astype(jnp.float32)            # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, length, *, block_k: int = 512,
                            interpret: bool = True):
    """q [B,Hq,D]; caches [B,Smax,Hkv,D]; length: int32 scalar (valid prefix)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    scale = 1.0 / math.sqrt(D)
    n_kv = S // block_k

    qp = q.reshape(B, Hkv, G, D).transpose(0, 1, 2, 3).reshape(B * Hkv, G, D)
    kp = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vp = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    lens = jnp.full((1,), length, jnp.int32)

    kern = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                             n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * Hkv, n_kv),
            in_specs=[
                pl.BlockSpec((1, G, D), lambda b, ik, lens: (b, 0, 0)),
                pl.BlockSpec((1, block_k, D), lambda b, ik, lens: (b, ik, 0)),
                pl.BlockSpec((1, block_k, D), lambda b, ik, lens: (b, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, G, D), lambda b, ik, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lens, qp, kp, vp)
    return out.reshape(B, Hkv, G, D).reshape(B, Hq, D)
