"""Causal GQA flash attention (prefill path) — Pallas TPU.

Grid (batch*kv_head, q_blocks, kv_blocks); online softmax with fp32 (m, l,
acc) VMEM scratch carried across the innermost kv sweep. Causality is
exploited structurally: fully-masked kv blocks are skipped via ``pl.when``
(zero MXU work), the diagonal block is masked elementwise — the same
"skip-aligned-blocks / handle-ragged-remainder" split HeteroInfer applies at
the engine level.

Block shapes: q rows x 128-lane kv columns; head_dim is the minor dim and
must be 128-aligned for MXU efficiency (pad at the ops layer otherwise).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, n_kv: int,
                  causal: bool, g: int):
    """q_ref: [block_q*g, D] (G query heads packed row-major per position),
    k_ref/v_ref: [block_k, D]. One (bq, bk) tile per invocation."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks entirely in the causal future (no compute issued at all)
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32)            # [bq*g, D]
        k = k_ref[0].astype(jnp.float32)            # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * g, block_k), 0) // g
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q * g, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, block_q: int = 256,
                           block_k: int = 256, interpret: bool = True):
    """q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D]; GQA handled by packing the G=Hq/Hkv
    query heads of one KV head into the q-block rows."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = 1.0 / math.sqrt(D)

    # [B*Hkv, Sq*G, D]: row-major (position, group) packing
    qp = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
          .reshape(B * Hkv, Sq * G, D)
    kp = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vp = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)

    n_kv = Sk // block_k
    grid = (B * Hkv, Sq // block_q, n_kv)
    kern = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                             block_k=block_k, n_kv=n_kv, causal=causal, g=G)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q * G, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q * G, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, Sq * G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4) \
              .reshape(B, Sq, Hq, D)
