"""jit'd wrapper for the flash-attention kernel (head-dim padding policy)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    """GQA flash attention; pads head_dim up to a 128 multiple (MXU lanes)."""
    D = q.shape[-1]
    Dp = -(-D // 128) * 128
    if Dp != D:
        padf = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, Dp - D)])
        # zero-padded head dims do not change q.k^T nor add output mass, but
        # the softmax scale must use the ORIGINAL D — kernel derives it from
        # the padded shape, so rescale q to compensate.
        q = padf(q) * (Dp / D) ** 0.5
        k, v = padf(k), padf(v)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out[..., :D]
