"""Pure-jnp oracle: dense causal GQA attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True):
    """q [B,Sq,Hq,D], k/v [B,Sk,Hkv,D] -> [B,Sq,Hq,D]."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] + (Sk - Sq)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
