"""End-to-end training driver (used by launch/train.py and the examples).

Wires: model + sharding rules + AdamW + data pipeline + checkpointing +
fault tolerance (heartbeat/straggler monitor, crash restart) + optional
int8-EF gradient compression on the DP reduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import (compress_grads_with_feedback,
                                           init_error)
from repro.distributed.sharding import activation_sharding, hidden_spec
from repro.models import build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticLM
from repro.serving.telemetry import Clock, MonotonicClock
from repro.training.fault_tolerance import (RestartPolicy, StepMonitor,
                                            run_resilient)


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    save_every: int = 50
    ckpt_dir: str = "artifacts/ckpt"
    grad_compression: bool = False
    seq_shard: bool = False        # SP only useful on real meshes
    opt: opt.AdamWConfig = opt.AdamWConfig()


def make_train_step(cfg, tcfg: TrainConfig, *, unroll: bool = False):
    model = build_model(cfg)

    def train_step(state, batch):
        def lf(p):
            loss, metrics = model.loss(p, batch["inputs"], batch["targets"],
                                       unroll=unroll)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        if tcfg.grad_compression:
            grads, new_err = compress_grads_with_feedback(
                grads, state["ef_error"])
        new_state, om = opt.apply_updates(
            {k: state[k] for k in ("params", "m", "v", "step")}, grads,
            tcfg.opt)
        if tcfg.grad_compression:
            new_state["ef_error"] = new_err
        return new_state, {"loss": loss, **metrics, **om}

    return model, jax.jit(train_step, donate_argnums=(0,))


def train(cfg, tcfg: TrainConfig, shape=None, *, data=None,
          fail_injector=None, log=print, clock: Optional[Clock] = None):
    clock = clock if clock is not None else MonotonicClock()
    model, step_fn = make_train_step(cfg, tcfg)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params, tcfg.opt)
    if tcfg.grad_compression:
        state["ef_error"] = init_error(params)

    seq = shape.seq_len if shape else 128
    batch = shape.global_batch if shape else 8
    data = data or SyntheticLM(cfg.vocab_size, seq, batch)
    ckpt = CheckpointManager(tcfg.ckpt_dir)

    losses = []

    def logged_step(state, batch):
        t0 = clock.now()
        state, metrics = step_fn(state, batch)
        step = int(state["step"])
        if step % tcfg.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            log(f"step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({clock.now() - t0:.2f}s)")
        return state, metrics

    state, metrics, monitor = run_resilient(
        tcfg.steps, state=state, data=data, step_fn=logged_step,
        ckpt=ckpt, save_every=tcfg.save_every,
        policy=RestartPolicy(), fail_injector=fail_injector, log=log,
        clock=clock)
    return state, losses, monitor
