"""Fault tolerance for the training launcher.

Mechanisms (single-controller process here; the contracts mirror multi-host):
  * Heartbeat/straggler monitor — a watchdog thread tracks per-step wall
    time; a step exceeding ``straggler_factor x`` the trailing median marks a
    straggler event (on real pods: triggers re-slicing / hot-spare swap; here:
    recorded + surfaced, and the step is retried if it raises).
  * Crash recovery — ``run_resilient`` wraps the step loop: on exception it
    restores the latest checkpoint + data state and continues, up to
    ``max_restarts``. Deterministic data (stepped RNG) makes the retrace
    bit-reproducible.
  * Elastic restart — restore() reshards onto whatever mesh the relaunched
    job has (see CheckpointManager.restore): scale-down survives node loss.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from statistics import median
from typing import Callable, Optional

from repro.serving.telemetry import Clock, MonotonicClock


@dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    window: int = 20
    times: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 5:
            med = median(self.times[-self.window:])
            if dt > self.straggler_factor * med:
                self.events.append({"step": step, "dt": dt, "median": med})
                is_straggler = True
        self.times.append(dt)
        return is_straggler


@dataclass
class RestartPolicy:
    max_restarts: int = 3
    restarts_used: int = 0


def run_resilient(n_steps: int, *, state, data, step_fn: Callable,
                  ckpt, save_every: int = 50,
                  monitor: Optional[StepMonitor] = None,
                  policy: Optional[RestartPolicy] = None,
                  fail_injector: Optional[Callable] = None,
                  log: Callable = print,
                  clock: Optional[Clock] = None):
    """Run the training loop with checkpoint/restart + straggler tracking.

    fail_injector(step) -> None | Exception — used by tests to simulate node
    failures at specific steps. ``clock`` feeds the straggler monitor's
    per-step durations (telemetry Clock protocol; MonotonicClock by
    default, FakeClock in tests so tier-1 never reads wall time).
    """
    monitor = monitor or StepMonitor()
    policy = policy or RestartPolicy()
    clock = clock if clock is not None else MonotonicClock()
    step = int(state["step"])
    metrics = {}
    while step < n_steps:
        try:
            t0 = clock.now()
            if fail_injector is not None:
                fail_injector(step)
            batch = data.next()
            state, metrics = step_fn(state, batch)
            dt = clock.now() - t0
            step += 1
            if monitor.record(step, dt):
                log(f"[ft] straggler at step {step}: {dt:.3f}s")
            if step % save_every == 0:
                ckpt.save(step, {"state": state, "data": data.state()})
        except Exception as e:  # noqa: BLE001 — the recovery path IS the feature
            policy.restarts_used += 1
            if policy.restarts_used > policy.max_restarts:
                raise
            ckpt.wait()          # let an in-flight async save commit first
            last = ckpt.latest_step()
            log(f"[ft] step {step} failed ({type(e).__name__}: {e}); "
                f"restart {policy.restarts_used}/{policy.max_restarts} "
                f"from checkpoint {last}")
            if last is None:
                raise
            restored = ckpt.restore(last, {"state": state,
                                           "data": data.state()})
            state = restored["state"]
            data.restore(restored["data"])
            step = int(state["step"])
    ckpt.save(n_steps, {"state": state, "data": data.state()},
              blocking=True)
    return state, metrics, monitor
