"""Token data pipeline: deterministic, checkpointable, host-prefetched.

``SyntheticLM`` generates structure-bearing token streams (Zipfian unigrams +
a short Markov mixer) so training loss actually decreases; ``PackedFile``
memory-maps a .bin of uint16/uint32 tokens and serves packed sequences.
Both expose ``state()``/``restore()`` so a restarted job resumes mid-epoch
(fault-tolerance contract), and a one-deep host prefetch thread overlaps
batch construction with the device step.
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, batch: int, *,
                 seed: int = 0, alpha: float = 1.1):
        self.vocab, self.seq, self.batch = vocab_size, seq_len, batch
        self.seed, self.alpha = seed, alpha
        self.step = 0
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.probs = (ranks ** -alpha) / np.sum(ranks ** -alpha)

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, st: dict):
        self.step, self.seed = st["step"], st["seed"]

    def next(self) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + self.step)
        self.step += 1
        toks = rng.choice(self.vocab, p=self.probs,
                          size=(self.batch, self.seq + 1)).astype(np.int32)
        # Markov-ish structure: every even position repeats prior token + 1
        toks[:, 2::2] = (toks[:, 1:-1:2] + 1) % self.vocab
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


class PackedFile:
    """Serves contiguous packed [batch, seq+1] windows from a token .bin."""

    def __init__(self, path: str | Path, vocab_size: int, seq_len: int,
                 batch: int, *, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab, self.seq, self.batch = vocab_size, seq_len, batch
        self.step = 0
        self.per_step = batch * (seq_len + 1)

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, st: dict):
        self.step = st["step"]

    def next(self) -> dict:
        n = len(self.tokens) - self.per_step
        off = (self.step * self.per_step) % max(n, 1)
        self.step += 1
        window = np.asarray(self.tokens[off: off + self.per_step],
                            dtype=np.int32).reshape(self.batch, self.seq + 1)
        window %= self.vocab
        return {"inputs": window[:, :-1], "targets": window[:, 1:]}


class Prefetcher:
    """One-deep background prefetch: overlaps host batch prep with device step."""

    def __init__(self, source, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            try:
                self.q.put(self.source.next(), timeout=0.5)
            except queue.Full:
                continue

    def next(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
