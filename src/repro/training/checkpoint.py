"""Fault-tolerant checkpointing: async background writes, atomic manifests,
elastic restore onto a different mesh.

Layout:  <dir>/step_<N>/
            manifest.json       (tree structure, shapes, dtypes, step, status)
            <leafpath>.npy      (one file per leaf, host-gathered)

Writes happen on a background thread (training continues — the analogue of
multi-host async checkpointing); ``finalize`` renames a COMMIT marker last so
a crash mid-write never yields a readable-but-corrupt checkpoint. ``restore``
takes the CURRENT mesh + sharding spec and device_puts each leaf with its new
sharding — elastic re-scale (save on (4,2), restore on (2,2), etc.).
"""
from __future__ import annotations

import json
import threading
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save --
    def save(self, step: int, state: Any, *, blocking: bool = False):
        """Snapshot to host memory synchronously (cheap), write asynchronously."""
        host = [(name, np.asarray(jax.device_get(leaf)))
                for name, leaf in _leaf_paths(state)]
        if self._thread is not None:
            self._thread.join()          # one outstanding write at a time

        def write():
            d = self.dir / f"step_{step:08d}.tmp"
            if d.exists():
                shutil.rmtree(d)
            d.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}}
            for name, arr in host:
                fn = name.replace("/", "__") + ".npy"
                logical_dtype = str(arr.dtype)
                if logical_dtype == "bfloat16":   # np.save can't roundtrip
                    np.save(d / fn, arr.view(np.uint16))
                else:
                    np.save(d / fn, arr)
                manifest["leaves"][name] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": logical_dtype}
            (d / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            d.rename(final)              # atomic commit
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- restore --
    def all_steps(self) -> list[int]:
        # uncommitted step_NNNNNNNN.tmp dirs (async write in flight) are not
        # checkpoints: only the atomic rename makes one visible
        return sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp")
                      and (p / "manifest.json").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Optional[Any] = None) -> Any:
        """Rebuild the state pytree; ``like`` provides structure/dtypes;
        ``shardings`` (same structure) re-shards onto the CURRENT mesh."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = dict(_leaf_paths(like))
        sh = dict(_leaf_paths(shardings)) if shardings is not None else {}
        out = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            tgt = leaves.get(name)
            if (tgt is not None and hasattr(tgt, "shape")
                    and tuple(arr.shape) != tuple(tgt.shape)):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {tgt.shape}")
            if not hasattr(tgt, "shape"):      # python scalar leaf
                out[name] = type(tgt)(arr) if tgt is not None else arr.item()
            elif name in sh and sh[name] is not None:
                out[name] = jax.device_put(arr, sh[name])
            else:
                out[name] = jax.device_put(arr)
        # reassemble into the pytree structure of `like`
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        rebuilt = []
        for path, leaf in flat:
            name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                            for p in path)
            val = out[name]
            rebuilt.append(val.astype(leaf.dtype)
                           if hasattr(leaf, "dtype") and hasattr(val, "astype")
                           else val)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), rebuilt)
