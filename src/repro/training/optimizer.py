"""AdamW with global-norm clipping (pure pytree implementation).

Moments are fp32 and shard exactly like the params (ZeRO-3-equivalent: the
param sharding rules already 2-D shard every large tensor over data x model).
``TrainState`` is a plain dict pytree so it serializes/reshards trivially.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_state(params: Any, cfg: AdamWConfig | None = None) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "params": params,
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(state: dict, grads: Any, cfg: AdamWConfig) -> tuple[dict, dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, state["params"], grads, state["m"], state["v"])
    params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"params": params, "m": m, "v": v, "step": step}
    return new_state, {"grad_norm": gn, "lr": lr}
