"""Production mesh builders.

A function (not a module-level constant) so importing never touches jax device
state. Single pod = 16x16 = 256 chips ("data", "model"); multi-pod adds a
leading "pod" axis (2 pods = 512 chips). Batch/FSDP dims shard over the
compound ("pod", "data") axes so N-pod scaling only grows the leading axis;
gradient reductions then naturally hierarchize: reduce-scatter over intra-pod
ICI first, cross-pod DCI last.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Compound batch/FSDP axes: ('pod','data') on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axes(mesh) -> tuple:
    return ("model",)
