import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder host devices back the production
meshes. Per cell this emits a JSON artifact with:
  - memory_analysis (proves the program fits per-device HBM)
  - cost_analysis   (FLOPs / bytes; per-device, post-partitioning)
  - collective op schedule + byte counts (parsed from compiled HLO)
Probe variants (--probe 1|2) compile reduced-depth UNROLLED programs used by
the roofline to recover true per-layer costs (scan bodies are counted once by
HLO cost analysis — see DESIGN.md §6).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multipod]
         [--probe 0|1|2] [--kv-mode auto|head|seq] [--out artifacts/...]
  python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import json
import sys
import traceback
from pathlib import Path


def _probe_cfg(cfg, n_units: int):
    """Reduce depth to n_units 'repeating units' (layers, or zamba periods)."""
    if cfg.ssm is not None:
        return cfg.with_(n_layers=n_units * cfg.ssm.attn_every)
    return cfg.with_(n_layers=n_units)


def _probe_shape(cfg, shape):
    """Cap probe sequence length for chunked-recurrence archs (rwkv) whose
    unrolled chunk loops would blow up HLO size; costs are linear in S and
    are rescaled by the roofline (field ``probe_seq_scale``)."""
    import dataclasses
    if shape.kind == "decode":
        return shape, 1.0
    # rwkv is strictly token-linear (attention-free) -> exact rescale.
    # zamba: capped at 8192 for compile-time reasons; the (1/attn_every of
    # layers) shared-attention quadratic component is underestimated by the
    # linear rescale — noted in EXPERIMENTS.md §Roofline.
    cap = 4096 if cfg.rwkv is not None else (8192 if cfg.ssm is not None
                                             else None)
    if cap and shape.seq_len > cap:
        scale = shape.seq_len / cap
        return dataclasses.replace(shape, seq_len=cap), scale
    return shape, 1.0


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             probe: int = 0, kv_mode: str = "auto", seq_shard: bool = True,
             serve_fsdp: bool = False, variant: str = "",
             out_dir: str = "artifacts/dryrun", clock=None) -> dict:
    import jax
    from repro.configs import get_config, SHAPES, cell_is_supported
    from repro.distributed.sharding import activation_sharding
    from repro.distributed.compat import set_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step_and_specs
    from repro.roofline.hlo_parse import collective_summary
    from repro.serving.telemetry import MonotonicClock

    # lower_s/compile_s read the injected clock (telemetry Clock protocol);
    # real wall time by default, FakeClock under test
    clock = clock if clock is not None else MonotonicClock()

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__probe{probe}" if probe else "")
    if variant:
        cell += f"__{variant}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "probe": probe, "kv_mode": kv_mode, "variant": variant,
           "serve_fsdp": serve_fsdp, "ok": False}

    ok, reason = cell_is_supported(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=reason, ok=True)
        return _save(rec, cell, out_dir)

    probe_scale = 1.0
    if probe:
        cfg = _probe_cfg(cfg, probe)
        shape, probe_scale = _probe_shape(cfg, shape)
    rec["probe_seq_scale"] = probe_scale
    rec["n_layers_used"] = cfg.n_layers

    t0 = clock.now()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with set_mesh(mesh):
            jf, args, act_spec = make_step_and_specs(
                cfg, mesh, shape, unroll=bool(probe), kv_mode=kv_mode,
                seq_shard=seq_shard, serve_fsdp=serve_fsdp)
            with activation_sharding(act_spec):
                lowered = jf.lower(*args)
            t1 = clock.now()
            compiled = lowered.compile()
            t2 = clock.now()
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)}
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "utilization operand", "bytes accessed output")}
        rec["cost"].setdefault("flops", float(ca.get("flops", 0.0)))
        hlo = compiled.as_text()
        rec["collectives"] = collective_summary(hlo)
        rec["hlo_bytes"] = len(hlo)
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["n_devices"] = mesh.size
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _save(rec, cell, out_dir)


def _save(rec: dict, cell: str, out_dir: str) -> dict:
    p = Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    (p / f"{cell}.json").write_text(json.dumps(rec, indent=1))
    status = "OK" if rec.get("ok") else "FAIL"
    if rec.get("skipped"):
        status = "SKIP"
    print(f"[dryrun] {cell}: {status}"
          + (f" compile={rec.get('compile_s')}s" if rec.get("ok") and not rec.get("skipped") else "")
          + (f" reason={rec.get('reason', rec.get('error', ''))[:120]}"
             if status != "OK" else ""))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--probe", type=int, default=0)
    ap.add_argument("--kv-mode", default="auto")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--serve-fsdp", action="store_true",
                    help="legacy: FSDP-shard weights in serving too "
                         "(the pre-i1 baseline)")
    ap.add_argument("--variant", default="",
                    help="artifact suffix for perf-iteration records")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        from repro.configs import ASSIGNED_ARCHS, SHAPES
        rc = 0
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                r = run_cell(arch, shape, multi_pod=args.multipod,
                             kv_mode=args.kv_mode, out_dir=args.out)
                rc |= 0 if r.get("ok") else 1
        sys.exit(rc)

    r = run_cell(args.arch, args.shape, multi_pod=args.multipod,
                 probe=args.probe, kv_mode=args.kv_mode,
                 seq_shard=not args.no_seq_shard,
                 serve_fsdp=args.serve_fsdp, variant=args.variant,
                 out_dir=args.out)
    sys.exit(0 if r.get("ok") else 1)


if __name__ == "__main__":
    main()
