"""Serving launcher: ``python -m repro.launch.serve --arch llama3-8b --smoke
--mode hetero-tensor --strategy hetero --requests 8``.

Runs the HeteroInfer engine (single-stream, paper-faithful) or the
continuous batcher (--batched) on synthetic prompts and prints tok/s.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="hetero-tensor",
                    choices=["xla", "mxu", "hetero-layer", "hetero-tensor"])
    ap.add_argument("--strategy", default="hetero",
                    choices=["online-prepare", "padding", "pipe", "hetero"])
    ap.add_argument("--no-fast-sync", action="store_true")
    ap.add_argument("--batched", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=300)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)

    if args.batched:
        from repro.serving.scheduler import ContinuousBatcher, Request
        cb = ContinuousBatcher(cfg, max_batch=4,
                               max_len=args.prompt_len + args.new_tokens + 8)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            rng.integers(8, args.prompt_len)
                                            ).astype(np.int32),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]
        t0 = time.perf_counter()
        cb.run(reqs)
        dt = time.perf_counter() - t0
        tok = sum(len(r.output) for r in reqs)
        print(f"batched: {args.requests} reqs, {tok} tokens in {dt:.2f}s "
              f"({tok / dt:.1f} tok/s)")
        return

    from repro.core.engine import InferenceEngine
    eng = InferenceEngine(cfg, mode=args.mode, prefill_strategy=args.strategy,
                          fast_sync=not args.no_fast_sync,
                          max_len=args.prompt_len + args.new_tokens + 8)
    prompt = rng.integers(0, cfg.vocab_size,
                          (1, args.prompt_len)).astype(np.int32)
    toks = eng.generate(jax.numpy.asarray(prompt), args.new_tokens)
    print(f"mode={args.mode} strategy={args.strategy} out={toks.shape} "
          f"{eng.stats.tokens_per_s()}")


if __name__ == "__main__":
    main()
