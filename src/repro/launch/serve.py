"""Serving launcher: ``python -m repro.launch.serve --arch llama3-8b --smoke
--mode hetero-tensor --strategy hetero --requests 8``.

Runs the HeteroInfer engine (single-stream, paper-faithful), the dense
continuous batcher (--batched), or the paged-KV batcher (--batched --paged,
with --block-size / --max-blocks / --decode-width sizing the shared pool)
on synthetic prompts and prints tok/s.

Paged mode fuses the engine into the serving path
(docs/heterogeneous-execution.md):
  --sync device     fused-window decode: one dispatch per --window decode
                    steps instead of per token (fast sync, §4.3)
  --sync host       per-token host-synced decode (the baseline arm)
  --engine-mode M   solver-planned prefill: admission-time prefill matmuls
                    run the PartitionSolver plan through HeteroCtx (§4.1/4.2)
  --mixed-batch     stage-parallel mixed batching: each step fuses one
                    prefill chunk of the admitting request into the decode
                    dispatch of the running lanes (§4.1-§4.3 at stage level)
  --max-prefill-chunk N
                    cap on prefill tokens fused per step (--mixed-batch)
  --spec-k K        speculative decoding: K drafts per round, one batched
                    K+1-position verify dispatch of the target per round
                    (serving/spec.py; VERIFY-planned matmuls under
                    --engine-mode)
  --spec-draft M    draft model config name (e.g. smollm-135m); omit for
                    self-speculation (the target drafts for itself)
  --prefix-cache    automatic prefix caching: closed sequences retire full
                    KV blocks into a content-hash cache, new admissions
                    share matching blocks and prefill only the uncached
                    suffix (pair with --shared-prefix to shape the
                    workload; --decode-width < --requests staggers closes
                    so later admissions actually hit)
  --weight-quant Q  serve quantized weights (int8 | w4a16): matmul sites
                    carry int8/packed-int4 codes + per-channel scales and
                    dispatch the in-VMEM-dequant MXU kernels (models/quant)
  --kv-quant int8   int8 paged KV pool: quantize-on-scatter with per-slot
                    bf16 scales — equal pool memory holds ~2x the tokens
  --tp N            tensor-parallel serving over an N-wide ``model`` mesh
                    axis: weights and the paged KV pool shard head-wise
                    (serving/layout.py), host bookkeeping stays replicated,
                    greedy streams stay bit-identical to --tp 1 (on CPU,
                    export XLA_FLAGS=--xla_force_host_platform_device_count=N
                    first; incompatible with --engine-mode)
  --stats           print the scheduler's unified stats() counter dict

Batched serving always runs through the async ingress
(serving/ingress.py): every request is timestamped against the wall clock
(serving/telemetry.py) and the run reports TTFT / TPOT / queue-delay
p50/p95/p99 plus goodput — in CLOSED-loop mode (default: all requests
arrive at t=0) as well as open loop:
  --open-loop       requests arrive on a seeded schedule instead of all
                    at once — the latency a real user sees under load
  --arrival P       arrival process: poisson (memoryless) or burst
                    (on-off at the same long-run rate)
  --rate R          mean arrival rate, requests/second
  --slo-ms MS       TTFT SLO: goodput counts only requests under it
  --priority-mix F  fraction of requests submitted LOW priority; blocked
                    high-priority arrivals may preempt their lanes (paged)
  --watermark N     admission backpressure: defer while admitting would
                    leave fewer than N free+cached blocks (paged)
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="hetero-tensor",
                    choices=["xla", "mxu", "hetero-layer", "hetero-tensor"])
    ap.add_argument("--strategy", default="hetero",
                    choices=["online-prepare", "padding", "pipe", "hetero"])
    ap.add_argument("--no-fast-sync", action="store_true")
    ap.add_argument("--batched", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="use the paged (block-table) KV cache batcher")
    ap.add_argument("--block-size", type=int, default=32,
                    help="tokens per KV block (paged mode)")
    ap.add_argument("--max-blocks", type=int, default=0,
                    help="pool size in blocks; 0 = sized from --requests")
    ap.add_argument("--decode-width", type=int, default=8,
                    help="compiled decode lanes (paged mode)")
    ap.add_argument("--sync", default="host", choices=["host", "device"],
                    help="paged decode arm: per-token host-synced loop vs "
                         "fused on-device windows (one dispatch per window)")
    ap.add_argument("--window", type=int, default=8,
                    help="decode steps per fused dispatch (--sync device)")
    ap.add_argument("--engine-mode", default=None,
                    choices=["xla", "mxu", "hetero-layer", "hetero-tensor"],
                    help="solver-planned paged prefill: route prefill "
                         "matmuls through the HeteroCtx in this mode")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (paged mode)")
    ap.add_argument("--mixed-batch", action="store_true",
                    help="stage-parallel mixed batching: fuse admission "
                         "prefill chunks into decode dispatches")
    ap.add_argument("--max-prefill-chunk", type=int, default=None,
                    metavar="N", dest="max_prefill_chunk",
                    help="max prefill tokens fused per scheduler step "
                         "(--mixed-batch; default: largest bucket)")
    ap.add_argument("--spec-k", type=int, default=None, metavar="K",
                    dest="spec_k",
                    help="speculative decoding: K drafts per round "
                         "(paged mode)")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    dest="spec_draft",
                    help="draft model config name (--spec-k; default: the "
                         "target drafts for itself)")
    ap.add_argument("--prefix-cache", action="store_true",
                    dest="prefix_cache",
                    help="automatic prefix caching: closed sequences retire "
                         "full KV blocks into a content-hash cache; new "
                         "admissions share matching blocks and prefill only "
                         "the uncached suffix (paged mode)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="LEN",
                    dest="shared_prefix",
                    help="give every request the same LEN-token system "
                         "prompt prefix (the prefix-cache workload shape)")
    ap.add_argument("--weight-quant", default=None, dest="weight_quant",
                    choices=["int8", "w4a16"],
                    help="serve quantized weights: int8 or packed-int4 "
                         "(W4A16) codes with per-output-channel scales "
                         "(paged mode)")
    ap.add_argument("--kv-quant", default=None, dest="kv_quant",
                    choices=["int8"],
                    help="quantize the paged KV pool to int8 codes with "
                         "per-token-slot scales (paged mode)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: shard weights + paged KV "
                         "pool over an N-wide 'model' mesh axis "
                         "(paged mode; needs N visible devices)")
    ap.add_argument("--stats", action="store_true",
                    help="print the scheduler's stats() counter dict")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    dest="trace_out",
                    help="write a Chrome trace-event JSON of the run "
                         "(Perfetto-loadable; batched mode)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    dest="metrics_out",
                    help="write a Prometheus-style text snapshot of the "
                         "run's counters/gauges/histograms (batched mode)")
    ap.add_argument("--plan-drift", action="store_true", dest="plan_drift",
                    help="print the solver plan-vs-actual drift table "
                         "(predicted vs observed us per (site, M, strategy);"
                         " needs --engine-mode for decision tags)")
    ap.add_argument("--open-loop", action="store_true", dest="open_loop",
                    help="open-loop serving: requests arrive on a seeded "
                         "schedule (--arrival/--rate) instead of all at t=0")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "burst"],
                    help="arrival process (--open-loop)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrival rate, requests/s (--open-loop)")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    dest="arrival_seed",
                    help="seed for the arrival schedule (--open-loop)")
    ap.add_argument("--slo-ms", type=float, default=None, dest="slo_ms",
                    metavar="MS",
                    help="TTFT SLO in ms: goodput counts only requests "
                         "whose first token lands under it")
    ap.add_argument("--priority-mix", type=float, default=0.0,
                    dest="priority_mix", metavar="F",
                    help="fraction of requests submitted LOW priority "
                         "(preemptible by blocked high-priority arrivals; "
                         "paged mode)")
    ap.add_argument("--watermark", type=int, default=0,
                    help="admission backpressure: defer admission while it "
                         "would leave fewer than N free+cached blocks "
                         "(paged mode)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=300)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if (args.sync == "device" or args.engine_mode or args.eos_id is not None
            or args.mixed_batch or args.spec_k is not None
            or args.prefix_cache or args.weight_quant or args.kv_quant
            or args.tp > 1) \
            and not (args.batched and args.paged):
        ap.error("--sync device / --engine-mode / --eos-id / --mixed-batch "
                 "/ --spec-k / --prefix-cache / --weight-quant / --kv-quant "
                 "/ --tp apply to the paged batcher: add --batched --paged")
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    if args.tp > 1 and args.engine_mode:
        ap.error("--tp and --engine-mode are mutually exclusive: the hetero "
                 "engine and the device mesh are separate axes")
    if args.max_prefill_chunk is not None and not args.mixed_batch:
        ap.error("--max-prefill-chunk applies to --mixed-batch")
    if args.spec_draft is not None and args.spec_k is None:
        ap.error("--spec-draft applies to --spec-k")
    if args.spec_k is not None and args.mixed_batch:
        ap.error("--spec-k and --mixed-batch are mutually exclusive")
    if args.open_loop and not args.batched:
        ap.error("--open-loop applies to the batched servers: add --batched")
    if (args.priority_mix or args.watermark) \
            and not (args.batched and args.paged):
        ap.error("--priority-mix / --watermark apply to the paged batcher: "
                 "add --batched --paged")
    if not 0.0 <= args.priority_mix <= 1.0:
        ap.error("--priority-mix must be in [0, 1]")
    if (args.trace_out or args.metrics_out or args.plan_drift) \
            and not args.batched:
        ap.error("--trace-out / --metrics-out / --plan-drift trace the "
                 "batched servers: add --batched")

    import jax
    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)

    if args.batched:
        from repro.serving.scheduler import ContinuousBatcher, PagedBatcher
        from repro.serving.telemetry import MonotonicClock
        from repro.serving.trace import Tracer
        max_len = args.prompt_len + args.new_tokens + 8
        # all serving timing flows through the injectable clock: the same
        # Telemetry machinery the deterministic tests pin, on a wall clock
        clock = MonotonicClock()
        tracing = bool(args.trace_out or args.metrics_out or args.plan_drift)
        tracer = Tracer(clock) if tracing else None
        if args.paged:
            spec = None
            if args.spec_k is not None:
                from repro.serving.spec import SpecConfig
                spec = SpecConfig(k=args.spec_k, draft=args.spec_draft,
                                  smoke=args.smoke)
            mesh = None
            if args.tp > 1:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(1, args.tp)
            num_blocks = args.max_blocks or (
                1 + args.requests * -(-max_len // args.block_size))
            # cap per-request tables at the longest possible request, not
            # the pool size: attention gathers a [W, NBmax*block_size] KV
            # view, so NBmax drives per-step cost
            cb = PagedBatcher(cfg, num_blocks=num_blocks,
                              block_size=args.block_size,
                              max_blocks_per_seq=-(-max_len
                                                   // args.block_size),
                              decode_width=args.decode_width,
                              sync=args.sync, window=args.window,
                              engine_mode=args.engine_mode,
                              eos_id=args.eos_id,
                              mixed_batch=args.mixed_batch,
                              max_prefill_chunk_per_step=args.max_prefill_chunk,
                              spec=spec, prefix_cache=args.prefix_cache,
                              weight_quant=args.weight_quant,
                              kv_quant=args.kv_quant, mesh=mesh,
                              tracer=tracer)
            label = (f"paged (bs={args.block_size}, "
                     f"blocks={num_blocks}, W={args.decode_width}, "
                     f"sync={args.sync}"
                     + (f", tp={args.tp}" if args.tp > 1 else "")
                     + (f", window={args.window}" if args.sync == "device"
                        else "")
                     + (f", engine={args.engine_mode}" if args.engine_mode
                        else "")
                     + (", mixed" if args.mixed_batch else "")
                     + (", prefix-cache" if args.prefix_cache else "")
                     + (f", weights={args.weight_quant}"
                        if args.weight_quant else "")
                     + (f", kv={args.kv_quant}" if args.kv_quant else "")
                     + (f", spec k={args.spec_k} "
                        f"draft={args.spec_draft or 'self'}"
                        if spec else "") + ")")
        else:
            cb = ContinuousBatcher(cfg, max_batch=4, max_len=max_len,
                                   tracer=tracer)
            label = "batched"
        if args.shared_prefix >= args.prompt_len - 8:
            ap.error("--shared-prefix must leave at least 8 tokens of "
                     "per-request tail below --prompt-len")
        sys_prompt = rng.integers(0, cfg.vocab_size,
                                  args.shared_prefix).astype(np.int32)
        prompts = [np.concatenate([
            sys_prompt,
            rng.integers(0, cfg.vocab_size,
                         rng.integers(8, args.prompt_len
                                      - args.shared_prefix)
                         ).astype(np.int32)])
            for _ in range(args.requests)]
        from repro.serving.ingress import AsyncServer, arrival_times, \
            open_loop_workload
        server = AsyncServer(cb, clock=clock,
                             admit_watermark=args.watermark)
        prios = [0 if rng.random() < args.priority_mix else 1
                 for _ in range(args.requests)]
        if args.open_loop:
            t_arr = arrival_times(args.arrival, args.rate, args.requests,
                                  args.arrival_seed)
        else:
            t_arr = np.zeros(args.requests)    # closed loop: all at t=0
        t0 = clock.now()
        handles = server.run_sync(open_loop_workload(
            prompts, [args.new_tokens] * args.requests, t0 + t_arr, prios))
        dt = clock.now() - t0
        tok = sum(len(h.tokens) for h in handles)
        loop = (f"open-loop {args.arrival}@{args.rate}/s" if args.open_loop
                else "closed-loop")
        print(f"{label}: {loop}, {args.requests} reqs, {tok} tokens in "
              f"{dt:.2f}s ({tok / dt:.1f} tok/s, peak concurrency "
              f"{cb.peak_active})")
        rep = server.report(slo_ms=args.slo_ms)
        for m in ("ttft_ms", "tpot_ms", "queue_delay_ms"):
            s = rep[m]
            if s["n"]:
                print(f"  {m.removesuffix('_ms')}: p50 {s['p50']:.1f} ms, "
                      f"p95 {s['p95']:.1f} ms, p99 {s['p99']:.1f} ms "
                      f"(n={s['n']})")
        good = rep["goodput_req_s"]
        print(f"  goodput: {good:.2f} req/s"
              + (f" under TTFT SLO {args.slo_ms:.0f} ms "
                 f"({100 * rep['slo_attainment']:.0f}% attainment)"
                 if args.slo_ms is not None else " (no SLO given)")
              + (f", {rep['preemptions']} preemptions"
                 if rep["preemptions"] else ""))
        if args.paged:
            print(f"  decode: {cb.decode_dispatches} host dispatches for "
                  f"{cb.decode_steps} decoded tokens "
                  f"({cb.decode_steps / max(cb.decode_dispatches, 1):.1f} "
                  f"tokens/dispatch)")
            print(f"  prefill: {cb.prefill_dispatches} standalone dispatches,"
                  f" {cb.fused_steps} chunks fused into decode dispatches "
                  f"({cb.total_dispatches} host dispatches total)")
            if args.spec_k is not None:
                s = cb.stats()
                print(f"  spec: {s['verify_dispatches']} verify dispatches, "
                      f"acceptance {s['acceptance_rate']:.2f} "
                      f"({s['accepted_tokens']}/{s['drafted_tokens']} drafts,"
                      f" draft={s['draft_model']})")
            if args.prefix_cache:
                s = cb.stats()
                print(f"  prefix-cache: {s['prefix_hits']} hit admissions, "
                      f"{s['prefix_tokens_reused']} prompt tokens reused, "
                      f"{s['cached_blocks']} blocks retained, "
                      f"{s['evictions']} evictions, "
                      f"{s['cow_copies']} CoW copies")
        if args.stats:
            print(f"  stats: {server.stats()}")
        if tracer is not None:
            if args.trace_out:
                tracer.save_chrome(args.trace_out)
                print(f"  trace: {tracer.n_events} events "
                      f"({tracer.dropped} dropped) -> {args.trace_out}")
            if args.metrics_out:
                tracer.save_prometheus(args.metrics_out)
                print(f"  metrics: -> {args.metrics_out}")
            if args.plan_drift:
                print(tracer.drift.format_table())
        return

    from repro.core.engine import InferenceEngine
    eng = InferenceEngine(cfg, mode=args.mode, prefill_strategy=args.strategy,
                          fast_sync=not args.no_fast_sync,
                          max_len=args.prompt_len + args.new_tokens + 8)
    prompt = rng.integers(0, cfg.vocab_size,
                          (1, args.prompt_len)).astype(np.int32)
    toks = eng.generate(jax.numpy.asarray(prompt), args.new_tokens)
    print(f"mode={args.mode} strategy={args.strategy} out={toks.shape} "
          f"{eng.stats.tokens_per_s()}")


if __name__ == "__main__":
    main()
