"""Step builders + input specs for every (arch x shape) cell.

``make_step_and_specs`` returns (jitted_fn, example_args) where every example
arg is a sharding-annotated ShapeDtypeStruct — lowering/compiling them is the
multi-pod dry-run. The same builders back the real train/serve launchers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import (activation_sharding, batch_sharding,
                                        batch_spec, cache_shardings,
                                        hidden_spec, param_shardings,
                                        split_kv_enabled)
from repro.models import build_model
from repro.training import optimizer as opt


def _sds(tree_shapes, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes, tree_shardings)


def _replicated(tree_shapes, mesh):
    rep = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rep),
        tree_shapes)


def _input_struct(cfg: ModelConfig, batch: int, seq: int):
    """Token ids, or precomputed modality-stub embeddings for [audio]."""
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def state_shapes(model, opt_cfg: opt.AdamWConfig):
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return jax.eval_shape(lambda p: opt.init_state(p, opt_cfg), params)


def state_shardings(model, mesh, opt_cfg: opt.AdamWConfig):
    st = state_shapes(model, opt_cfg)
    psh = param_shardings(st["params"], mesh)
    return {
        "params": psh, "m": psh, "v": psh,
        "step": NamedSharding(mesh, P()),
    }


def build_train_step(cfg: ModelConfig, mesh, *, unroll: bool = False,
                     opt_cfg: Optional[opt.AdamWConfig] = None,
                     seq_shard: bool = True):
    model = build_model(cfg)
    opt_cfg = opt_cfg or opt.AdamWConfig()
    act_spec = hidden_spec(mesh, seq_shard=seq_shard)

    def train_step(state, batch):
        def lf(p):
            loss, metrics = model.loss(p, batch["inputs"], batch["targets"],
                                       unroll=unroll)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        new_state, om = opt.apply_updates(state, grads, opt_cfg)
        return new_state, {"loss": loss, **metrics, **om}

    ssh = state_shardings(model, mesh, opt_cfg)
    jf = jax.jit(train_step, out_shardings=(ssh, None), donate_argnums=(0,))
    return jf, model, ssh, act_spec


def train_example_args(cfg, model, mesh, shape: ShapeSpec, ssh,
                       opt_cfg: Optional[opt.AdamWConfig] = None):
    opt_cfg = opt_cfg or opt.AdamWConfig()
    st = state_shapes(model, opt_cfg)
    state_sds = _sds(st, ssh)
    B, S = shape.global_batch, shape.seq_len
    inp = _input_struct(cfg, B, S)
    bspec = {"inputs": batch_sharding(mesh, inp.shape),
             "targets": batch_sharding(mesh, (B, S))}
    batch_sds = _sds({"inputs": inp,
                      "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)},
                     bspec)
    return (state_sds, batch_sds)


def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeSpec, *,
                     unroll: bool = False, kv_mode: str = "auto",
                     serve_fsdp: bool = False):
    """Prefill or decode step per the shape kind (encoder archs: encode).

    serve_fsdp=False: weights TP-only (replicated over data) — serving must
    not pay per-step parameter all-gathers (§Perf decode/i1)."""
    model = build_model(cfg)
    act_spec = hidden_spec(mesh, seq_shard=(shape.kind != "decode"))
    psh = param_shardings(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))), mesh,
        fsdp=serve_fsdp)

    if cfg.encoder_only:
        def encode(params, inputs):
            return model.encode(params, inputs, unroll=unroll)
        jf = jax.jit(encode)
        return jf, model, psh, None, act_spec

    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(batch=shape.global_batch,
                                 max_len=shape.seq_len))
    m_size = mesh.shape["model"]
    resolved_kv = kv_mode
    if resolved_kv == "auto" and not cfg.attn_free:
        resolved_kv = "head" if cfg.n_kv_heads % m_size == 0 else "seq"
    csh = cache_shardings(cache_shapes, mesh, cfg, kv_mode=resolved_kv)
    use_split = (shape.kind == "decode" and resolved_kv == "seq"
                 and not cfg.attn_free and shape.seq_len % m_size == 0)

    if shape.kind == "prefill":
        def step(params, tokens, cache):
            return model.prefill(params, tokens, cache, unroll=unroll)
    else:
        def step(params, token, cache):
            with split_kv_enabled(use_split):
                return model.decode_step(params, token, cache, unroll=unroll)

    jf = jax.jit(step, out_shardings=(None, csh), donate_argnums=(2,))
    return jf, model, psh, (cache_shapes, csh), act_spec


def serve_example_args(cfg, model, mesh, shape: ShapeSpec, psh, cache_info):
    params_sds = _sds(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))), psh)
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_only:
        tok = _input_struct(cfg, B, S)
        tok = jax.ShapeDtypeStruct(tok.shape, tok.dtype,
                                   sharding=batch_sharding(mesh, tok.shape))
        return (params_sds, tok)
    cache_shapes, csh = cache_info
    cache_sds = _sds(cache_shapes, csh)
    if shape.kind == "prefill":
        tok = _input_struct(cfg, B, S)
    else:
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok = jax.ShapeDtypeStruct(tok.shape, tok.dtype,
                               sharding=batch_sharding(mesh, tok.shape))
    return (params_sds, tok, cache_sds)


def make_step_and_specs(cfg: ModelConfig, mesh, shape: ShapeSpec, *,
                        unroll: bool = False, kv_mode: str = "auto",
                        seq_shard: bool = True, serve_fsdp: bool = False):
    """One-stop builder: returns (jitted_step, example_args, act_spec)."""
    if shape.kind == "train":
        jf, model, ssh, act_spec = build_train_step(cfg, mesh, unroll=unroll,
                                                    seq_shard=seq_shard)
        args = train_example_args(cfg, model, mesh, shape, ssh)
    else:
        jf, model, psh, cache_info, act_spec = build_serve_step(
            cfg, mesh, shape, unroll=unroll, kv_mode=kv_mode,
            serve_fsdp=serve_fsdp)
        args = serve_example_args(cfg, model, mesh, shape, psh, cache_info)
    return jf, args, act_spec
