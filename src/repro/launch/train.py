"""Training launcher: ``python -m repro.launch.train --arch smollm-135m
[--smoke] [--steps N] [--compress] [--seq N --batch N]``.

On this CPU container, use --smoke (reduced config). On a real pod the same
entry point runs the full config under make_production_mesh().
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import ShapeSpec
    from repro.training.train_loop import TrainConfig, train

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(steps=args.steps, grad_compression=args.compress,
                       ckpt_dir=args.ckpt_dir)
    state, losses, monitor = train(cfg, tcfg, shape)
    first, last = losses[0][1], losses[-1][1]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({len(monitor.events)} straggler events)")


if __name__ == "__main__":
    main()
