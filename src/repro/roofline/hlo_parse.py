"""Parse collective ops + byte counts out of compiled HLO text.

``cost_analysis`` does not report collective traffic, so we extract every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
from the post-optimization HLO and sum operand bytes, tracking replica-group
sizes so ring-traffic factors can be applied (see roofline.analysis).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %ag = bf16[2,1024]{1,0} all-gather(%x), replica_groups=...
#        %t = (f32[8]{0}, f32[4]{0}) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    # op -> [count, total_bytes, typical group size]
    ops: dict = field(default_factory=lambda: defaultdict(lambda: [0, 0, 1]))

    def as_dict(self) -> dict:
        return {k: {"count": v[0], "bytes": v[1], "group": v[2]}
                for k, v in self.ops.items()}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:      # async pair: count only the -start
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shapes"))
        gm = _GROUPS_RE.search(line)
        if gm:
            group = gm.group(1).count(",") + 1
        else:
            gm2 = _GROUPS2_RE.search(line)
            group = int(gm2.group(2)) if gm2 else 1
        rec = stats.ops[op]
        rec[0] += 1
        rec[1] += nbytes
        rec[2] = max(rec[2], group)
    return stats


def collective_summary(hlo_text: str) -> dict:
    return parse_collectives(hlo_text).as_dict()
