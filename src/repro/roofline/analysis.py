"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / peak_FLOPs          (per-chip: the compiled
                    module is the post-partitioning per-device program)
  memory term     = HLO_bytes / HBM_bw
  collective term = sum over collective ops of ring-traffic(bytes, group) / ICI_bw

Scan-correction: the full program scans over layers, and HLO cost analysis
counts a while body ONCE (verified empirically — see DESIGN.md §6). True
totals are recovered from two UNROLLED probe compiles:
    total = probe1 + (units - 1) * (probe2 - probe1)
where a "unit" is a layer (or a zamba period). RWKV probes cap the sequence
(linear-cost arch) and rescale by ``probe_seq_scale``.

MODEL_FLOPS sanity: 6*N_active*tokens (train) / 2*N_active*tokens (serve);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/padding waste.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

from repro.configs import SHAPES, get_config
from repro.core.characteristics import V5E

HBM_PER_CHIP = 16 * 2 ** 30          # v5e

RING_FACTORS = {    # effective bytes-on-wire multiplier given parsed result size
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def _units(cfg) -> int:
    if cfg.ssm is not None:
        return cfg.n_layers // cfg.ssm.attn_every
    return cfg.n_layers


def _load(out_dir: Path, cell: str) -> Optional[dict]:
    p = out_dir / f"{cell}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _coll_seconds(coll: dict, spec=V5E) -> float:
    t = 0.0
    for op, rec in coll.items():
        f = RING_FACTORS.get(op, lambda n: 1.0)(rec.get("group", 1))
        t += rec["bytes"] * f / (spec.ici_bw * spec.ici_links)
    return t


def _coll_bytes(coll: dict) -> float:
    return sum(rec["bytes"] for rec in coll.values())


def _combine(base: dict, p1: dict, p2: dict, units: int) -> dict:
    """Recover true per-device totals from the probe pair."""
    scale = p1.get("probe_seq_scale", 1.0)

    def field(v1, v2):
        # probe1 = 1 unit (+ embed/head), probe2 = 2 units -> delta = 1 unit
        return v1 + (units - 1) * (v2 - v1)

    flops = field(p1["cost"]["flops"], p2["cost"]["flops"]) * scale
    nbytes = field(p1["cost"]["bytes accessed"],
                   p2["cost"]["bytes accessed"]) * scale
    cb1, cb2 = _coll_bytes(p1["collectives"]), _coll_bytes(p2["collectives"])
    cs1, cs2 = _coll_seconds(p1["collectives"]), _coll_seconds(p2["collectives"])
    coll_bytes = field(cb1, cb2) * scale
    coll_s = field(cs1, cs2) * scale
    return {"flops": flops, "bytes": nbytes, "coll_bytes": coll_bytes,
            "coll_s": coll_s}


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: float = 0.0
    hbm_gb_per_chip: float = 0.0
    dominant: str = ""
    bound_time_s: float = 0.0
    roofline_fraction: float = 0.0
    note: str = ""

    def row(self) -> str:
        if self.skipped:
            return (f"| {self.arch} | {self.shape} | — | — | — | — | — | "
                    f"SKIP: {self.reason} |")
        return (f"| {self.arch} | {self.shape} | {self.compute_s*1e3:.2f} | "
                f"{self.memory_s*1e3:.2f} | {self.collective_s*1e3:.2f} | "
                f"{self.dominant} | {self.useful_ratio:.2f} | "
                f"{self.roofline_fraction:.2f} | {self.note} |")


def model_flops_for(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_params_active
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def analyze_cell(arch: str, shape_name: str, *, mesh: str = "pod16x16",
                 out_dir: str | Path = "artifacts/dryrun",
                 spec=V5E) -> CellRoofline:
    out_dir = Path(out_dir)
    base = _load(out_dir, f"{arch}__{shape_name}__{mesh}")
    cell = CellRoofline(arch=arch, shape=shape_name, mesh=mesh, ok=False)
    if base is None:
        cell.reason = "missing artifact"
        return cell
    if base.get("skipped"):
        cell.skipped, cell.reason, cell.ok = True, base["reason"], True
        return cell
    if not base.get("ok"):
        cell.reason = base.get("error", "failed")
        return cell

    cfg = get_config(arch)
    p1 = _load(out_dir, f"{arch}__{shape_name}__pod16x16__probe1")
    p2 = _load(out_dir, f"{arch}__{shape_name}__pod16x16__probe2")
    n_dev = base.get("n_devices", 256)
    mem = base.get("memory", {})
    cell.hbm_gb_per_chip = (mem.get("argument_size_in_bytes", 0)
                            + mem.get("temp_size_in_bytes", 0)
                            + mem.get("output_size_in_bytes", 0)
                            - mem.get("alias_size_in_bytes", 0)) / 2 ** 30

    if p1 and p2 and p1.get("ok") and p2.get("ok"):
        tot = _combine(base, p1, p2, _units(cfg))
        src = "probe-pair"
    else:   # fallback: raw full-program numbers (scan bodies undercounted)
        tot = {"flops": base["cost"]["flops"],
               "bytes": base["cost"]["bytes accessed"],
               "coll_bytes": _coll_bytes(base["collectives"]),
               "coll_s": _coll_seconds(base["collectives"])}
        src = "scan-raw(undercounted)"

    cell.compute_s = tot["flops"] / spec.peak_flops_bf16
    cell.memory_s = tot["bytes"] / spec.hbm_bw
    cell.collective_s = tot["coll_s"]
    cell.model_flops = model_flops_for(arch, shape_name)
    cell.hlo_flops_global = tot["flops"] * n_dev
    cell.useful_ratio = (cell.model_flops / cell.hlo_flops_global
                         if cell.hlo_flops_global else 0.0)
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.dominant = max(terms, key=terms.get)
    cell.bound_time_s = max(terms.values())
    # roofline fraction: the cell's physical lower bound over the dominant
    # term. Decode is bandwidth-bound by nature: its bound is streaming the
    # weights + cache once per token, not the (trivial) matvec FLOPs.
    shape = SHAPES[shape_name]
    ideal_s = cell.model_flops / (n_dev * spec.peak_flops_bf16)
    if shape.kind == "decode":
        w_bytes = cfg.n_params_active * 2
        if cfg.rwkv is not None:
            state = cfg.n_layers * shape.global_batch * cfg.d_model * \
                cfg.rwkv.head_dim * 4
        elif cfg.ssm is not None:
            d_in = cfg.ssm.expand * cfg.d_model
            nh = d_in // cfg.ssm.head_dim
            state = cfg.n_layers * shape.global_batch * nh * \
                cfg.ssm.head_dim * cfg.ssm.d_state * 4
            state += (cfg.n_layers // cfg.ssm.attn_every) * \
                shape.global_batch * shape.seq_len * cfg.n_kv_heads * \
                cfg.head_dim * 2 * 2
        else:
            state = cfg.n_layers * shape.global_batch * shape.seq_len * \
                cfg.n_kv_heads * cfg.head_dim * 2 * 2
        ideal_s = max(ideal_s, (w_bytes + state) / n_dev / spec.hbm_bw)
    cell.roofline_fraction = (ideal_s / cell.bound_time_s
                              if cell.bound_time_s else 0.0)
    cell.note = src
    cell.ok = True
    return cell


def analyze_all(out_dir: str | Path = "artifacts/dryrun") -> list[CellRoofline]:
    from repro.configs import ASSIGNED_ARCHS
    cells = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            cells.append(analyze_cell(arch, shape, out_dir=out_dir))
    return cells


def markdown_table(cells: list[CellRoofline]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | useful ratio | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return "\n".join([hdr] + [c.row() for c in cells])


def main():
    cells = analyze_all()
    print(markdown_table(cells))
    Path("artifacts/roofline.json").write_text(json.dumps(
        [vars(c) for c in cells], indent=1))


if __name__ == "__main__":
    main()
